//! The workflow graph — OpenMOLE's "puzzle" (paper §2.1).
//!
//! A puzzle is a set of [capsules](Capsule) (task + hooks + execution
//! environment) linked by transitions:
//!
//! * **direct** — plain dataflow edge;
//! * **explore** — fan-out: a [`Sampling`] expands the incoming context
//!   into many, and the downstream capsule runs once per sample (this is
//!   the "natural parallelism construct" the paper emphasises);
//! * **aggregate** — fan-in barrier: collects every result of the matching
//!   fan-out and forwards one context whose variables are arrays.

use std::sync::Arc;

use crate::dsl::hook::Hook;
use crate::dsl::source::Source;
use crate::dsl::task::Task;
use crate::environment::Environment;
use crate::error::{Error, Result};
use crate::exploration::sampling::Sampling;

/// Index of a capsule within its puzzle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CapsuleId(pub usize);

/// A task plus its sources, observation hooks and (optional) execution
/// environment.
pub struct Capsule {
    pub task: Arc<dyn Task>,
    pub sources: Vec<Arc<dyn Source>>,
    pub hooks: Vec<Arc<dyn Hook>>,
    pub environment: Option<Arc<dyn Environment>>,
}

/// A dataflow edge.
pub enum Transition {
    Direct {
        from: CapsuleId,
        to: CapsuleId,
    },
    Explore {
        from: CapsuleId,
        to: CapsuleId,
        sampling: Arc<dyn Sampling>,
    },
    Aggregate {
        from: CapsuleId,
        to: CapsuleId,
    },
}

impl Transition {
    pub fn from(&self) -> CapsuleId {
        match self {
            Transition::Direct { from, .. }
            | Transition::Explore { from, .. }
            | Transition::Aggregate { from, .. } => *from,
        }
    }

    pub fn to(&self) -> CapsuleId {
        match self {
            Transition::Direct { to, .. }
            | Transition::Explore { to, .. }
            | Transition::Aggregate { to, .. } => *to,
        }
    }
}

/// The workflow graph. Build with the fluent methods, validate, then hand
/// to [`crate::workflow::MoleExecution`].
#[derive(Default)]
pub struct Puzzle {
    pub capsules: Vec<Capsule>,
    pub transitions: Vec<Transition>,
    entry: Option<CapsuleId>,
}

impl Puzzle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a capsule wrapping `task`.
    pub fn capsule(&mut self, task: Arc<dyn Task>) -> CapsuleId {
        self.capsules.push(Capsule {
            task,
            sources: Vec::new(),
            hooks: Vec::new(),
            environment: None,
        });
        CapsuleId(self.capsules.len() - 1)
    }

    /// Attach a hook (`capsule hook ToStringHook(...)`).
    pub fn hook(&mut self, c: CapsuleId, hook: Arc<dyn Hook>) -> &mut Self {
        self.capsules[c.0].hooks.push(hook);
        self
    }

    /// Attach a source (`capsule source CSVSource(...)`): its variables are
    /// merged into the capsule's incoming context before each run.
    pub fn source(&mut self, c: CapsuleId, source: Arc<dyn Source>) -> &mut Self {
        self.capsules[c.0].sources.push(source);
        self
    }

    /// Delegate a capsule's jobs to an environment (`island on env` — the
    /// paper's one-line environment switch).
    pub fn on(&mut self, c: CapsuleId, env: Arc<dyn Environment>) -> &mut Self {
        self.capsules[c.0].environment = Some(env);
        self
    }

    /// Plain transition (`a -- b`).
    pub fn direct(&mut self, from: CapsuleId, to: CapsuleId) -> &mut Self {
        self.transitions.push(Transition::Direct { from, to });
        self
    }

    /// Fan-out: run `to` once per sample of `sampling` (`a -< b`).
    pub fn explore(
        &mut self,
        from: CapsuleId,
        sampling: Arc<dyn Sampling>,
        to: CapsuleId,
    ) -> &mut Self {
        self.transitions.push(Transition::Explore { from, to, sampling });
        self
    }

    /// Fan-in barrier (`b >- c`): aggregates the fan-out's results.
    pub fn aggregate(&mut self, from: CapsuleId, to: CapsuleId) -> &mut Self {
        self.transitions.push(Transition::Aggregate { from, to });
        self
    }

    /// Set the entry capsule. Defaults to capsule 0.
    pub fn entry(&mut self, c: CapsuleId) -> &mut Self {
        self.entry = Some(c);
        self
    }

    pub fn entry_capsule(&self) -> CapsuleId {
        self.entry.unwrap_or(CapsuleId(0))
    }

    pub fn outgoing(&self, c: CapsuleId) -> impl Iterator<Item = &Transition> {
        self.transitions.iter().filter(move |t| t.from() == c)
    }

    /// Terminal capsules: results arriving here are execution outputs.
    pub fn is_terminal(&self, c: CapsuleId) -> bool {
        self.outgoing(c).next().is_none()
    }

    /// Structural validation: ids in range, entry exists, no cycles.
    pub fn validate(&self) -> Result<()> {
        if self.capsules.is_empty() {
            return Err(Error::InvalidWorkflow("no capsules".into()));
        }
        let n = self.capsules.len();
        for t in &self.transitions {
            if t.from().0 >= n || t.to().0 >= n {
                return Err(Error::InvalidWorkflow(format!(
                    "transition references capsule out of range ({} -> {})",
                    t.from().0,
                    t.to().0
                )));
            }
        }
        if self.entry_capsule().0 >= n {
            return Err(Error::InvalidWorkflow("entry out of range".into()));
        }
        // cycle detection (transitions are a DAG in this engine)
        let mut state = vec![0u8; n]; // 0=unvisited 1=on-stack 2=done
        fn dfs(p: &Puzzle, c: usize, state: &mut [u8]) -> Result<()> {
            state[c] = 1;
            for t in p.outgoing(CapsuleId(c)) {
                let next = t.to().0;
                match state[next] {
                    0 => dfs(p, next, state)?,
                    1 => {
                        return Err(Error::InvalidWorkflow(format!(
                            "cycle through capsule {next}"
                        )))
                    }
                    _ => {}
                }
            }
            state[c] = 2;
            Ok(())
        }
        for c in 0..n {
            if state[c] == 0 {
                dfs(self, c, &mut state)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::task::IdentityTask;

    fn id_task() -> Arc<dyn Task> {
        Arc::new(IdentityTask::new("id"))
    }

    #[test]
    fn builds_and_validates_linear_chain() {
        let mut p = Puzzle::new();
        let a = p.capsule(id_task());
        let b = p.capsule(id_task());
        p.direct(a, b);
        assert!(p.validate().is_ok());
        assert!(!p.is_terminal(a));
        assert!(p.is_terminal(b));
    }

    #[test]
    fn detects_cycles() {
        let mut p = Puzzle::new();
        let a = p.capsule(id_task());
        let b = p.capsule(id_task());
        p.direct(a, b);
        p.direct(b, a);
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Puzzle::new().validate().is_err());
    }

    #[test]
    fn entry_defaults_to_first() {
        let mut p = Puzzle::new();
        let a = p.capsule(id_task());
        assert_eq!(p.entry_capsule(), a);
    }
}
