//! The workflow graph — OpenMOLE's "puzzle" (paper §2.1).
//!
//! A puzzle is a set of [capsules](Capsule) (task + hooks + execution
//! environment) linked by transitions:
//!
//! * **direct** — plain dataflow edge;
//! * **explore** — fan-out: a [`Sampling`] expands the incoming context
//!   into many, and the downstream capsule runs once per sample (this is
//!   the "natural parallelism construct" the paper emphasises);
//! * **aggregate** — fan-in barrier: collects every result of the matching
//!   fan-out and forwards one context whose variables are arrays.
//!
//! Construct puzzles with [`crate::dsl::PuzzleBuilder`] (MoleDSL v2); the
//! mutating methods on `Puzzle` itself survive as deprecated shims for one
//! release.
//!
//! # Validation (MoleDSL v2)
//!
//! [`Puzzle::validate`] proves, before any job is submitted:
//!
//! * **shape** — ids in range, entry exists, no cycles (iterative
//!   traversal: a generated million-capsule chain cannot overflow the
//!   stack), every capsule reachable from the entry;
//! * **explore/aggregate pairing** — each aggregate transition closes an
//!   enclosing explore, and no capsule is reachable at two different
//!   exploration depths;
//! * **typed dataflow** — every declared task input is supplied, with a
//!   compatible [`VarType`], by upstream outputs, sources, sampling
//!   columns or defaults. Errors name the offending capsule and variable.
//!
//! The dataflow pass is *sound for its errors, best-effort for its
//! silence*: a reported error is a genuine mis-wiring, but a task with
//! undeclared outputs, a context-only sampling or an undeclarable source
//! opens the flow — unknown extra variables may exist and any known
//! variable may have been overwritten — after which missing-input errors
//! are suppressed and known types are demoted to unknown, rather than
//! inventing errors. Declared interfaces buy stronger guarantees —
//! exactly the paper's §2.1 argument for a typed DSL.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::core::{Context, Value, VarType};
use crate::dsl::hook::Hook;
use crate::dsl::source::Source;
use crate::dsl::task::Task;
use crate::environment::Environment;
use crate::error::{Error, Result};
use crate::exploration::matrix::ColumnKind;
use crate::exploration::sampling::Sampling;

/// Index of a capsule within its puzzle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CapsuleId(pub usize);

/// A task plus its sources, observation hooks and (optional) execution
/// environment.
pub struct Capsule {
    pub task: Arc<dyn Task>,
    pub sources: Vec<Arc<dyn Source>>,
    pub hooks: Vec<Arc<dyn Hook>>,
    pub environment: Option<Arc<dyn Environment>>,
}

/// A dataflow edge.
pub enum Transition {
    Direct {
        from: CapsuleId,
        to: CapsuleId,
    },
    Explore {
        from: CapsuleId,
        to: CapsuleId,
        sampling: Arc<dyn Sampling>,
    },
    Aggregate {
        from: CapsuleId,
        to: CapsuleId,
    },
}

impl Transition {
    pub fn from(&self) -> CapsuleId {
        match self {
            Transition::Direct { from, .. }
            | Transition::Explore { from, .. }
            | Transition::Aggregate { from, .. } => *from,
        }
    }

    pub fn to(&self) -> CapsuleId {
        match self {
            Transition::Direct { to, .. }
            | Transition::Explore { to, .. }
            | Transition::Aggregate { to, .. } => *to,
        }
    }
}

/// The set of variables statically known to flow into/out of a capsule.
/// `ty: None` = present with unknown type; `open` = unknown extra
/// variables may also be present (undeclared outputs, context-only
/// samplings, undeclarable sources).
#[derive(Clone, Default)]
struct FlowEnv {
    vars: BTreeMap<String, Option<VarType>>,
    open: bool,
}

impl FlowEnv {
    fn from_context(ctx: &Context) -> Self {
        FlowEnv {
            vars: ctx
                .names()
                .map(|n| (n.to_string(), ctx.get_raw(n).and_then(Value::var_type)))
                .collect(),
            open: false,
        }
    }

    /// Unknown writes may occur from here on: suppress missing-input
    /// errors downstream AND demote every known type to unknown — an
    /// undeclared write may overwrite any variable with any type, so a
    /// type retained across this point could manufacture a false
    /// mismatch (the pass must stay sound for its errors).
    fn open_unknown(&mut self) {
        self.open = true;
        for ty in self.vars.values_mut() {
            *ty = None;
        }
    }

    /// A variable is guaranteed present only when every delivering path
    /// guarantees it; a known type survives only when the paths agree.
    fn intersect(&self, other: &FlowEnv) -> FlowEnv {
        let mut vars = BTreeMap::new();
        for (name, ty) in &self.vars {
            if let Some(other_ty) = other.vars.get(name) {
                let merged = match (ty, other_ty) {
                    (Some(a), Some(b)) if a == b => Some(a.clone()),
                    _ => None,
                };
                vars.insert(name.clone(), merged);
            }
        }
        FlowEnv {
            vars,
            open: self.open || other.open,
        }
    }
}

/// The workflow graph. Build with [`crate::dsl::PuzzleBuilder`], validate,
/// then hand to [`crate::workflow::MoleExecution`].
#[derive(Default)]
pub struct Puzzle {
    pub capsules: Vec<Capsule>,
    pub transitions: Vec<Transition>,
    entry: Option<CapsuleId>,
}

impl Puzzle {
    pub fn new() -> Self {
        Self::default()
    }

    // ------------------------------------------------------------------
    // crate-internal mutators: the single implementation behind both the
    // PuzzleBuilder and the deprecated public shims below
    // ------------------------------------------------------------------

    pub(crate) fn add_capsule(&mut self, task: Arc<dyn Task>) -> CapsuleId {
        self.capsules.push(Capsule {
            task,
            sources: Vec::new(),
            hooks: Vec::new(),
            environment: None,
        });
        CapsuleId(self.capsules.len() - 1)
    }

    pub(crate) fn add_hook(&mut self, c: CapsuleId, hook: Arc<dyn Hook>) {
        self.capsules[c.0].hooks.push(hook);
    }

    pub(crate) fn add_source(&mut self, c: CapsuleId, source: Arc<dyn Source>) {
        self.capsules[c.0].sources.push(source);
    }

    pub(crate) fn set_environment(&mut self, c: CapsuleId, env: Arc<dyn Environment>) {
        self.capsules[c.0].environment = Some(env);
    }

    pub(crate) fn add_direct(&mut self, from: CapsuleId, to: CapsuleId) {
        self.transitions.push(Transition::Direct { from, to });
    }

    pub(crate) fn add_explore(
        &mut self,
        from: CapsuleId,
        sampling: Arc<dyn Sampling>,
        to: CapsuleId,
    ) {
        self.transitions.push(Transition::Explore { from, to, sampling });
    }

    pub(crate) fn add_aggregate(&mut self, from: CapsuleId, to: CapsuleId) {
        self.transitions.push(Transition::Aggregate { from, to });
    }

    pub(crate) fn set_entry(&mut self, c: CapsuleId) {
        self.entry = Some(c);
    }

    // ------------------------------------------------------------------
    // deprecated v1 mutators (one release of grace; use PuzzleBuilder)
    // ------------------------------------------------------------------

    /// Add a capsule wrapping `task`.
    #[deprecated(note = "use dsl::PuzzleBuilder::task / ::capsule (MoleDSL v2)")]
    pub fn capsule(&mut self, task: Arc<dyn Task>) -> CapsuleId {
        self.add_capsule(task)
    }

    /// Attach a hook (`capsule hook ToStringHook(...)`).
    #[deprecated(note = "use dsl::CapsuleHandle::hook (MoleDSL v2)")]
    pub fn hook(&mut self, c: CapsuleId, hook: Arc<dyn Hook>) -> &mut Self {
        self.add_hook(c, hook);
        self
    }

    /// Attach a source (`capsule source CSVSource(...)`): its variables are
    /// merged into the capsule's incoming context before each run.
    #[deprecated(note = "use dsl::CapsuleHandle::source (MoleDSL v2)")]
    pub fn source(&mut self, c: CapsuleId, source: Arc<dyn Source>) -> &mut Self {
        self.add_source(c, source);
        self
    }

    /// Delegate a capsule's jobs to an environment (`island on env` — the
    /// paper's one-line environment switch).
    #[deprecated(note = "use dsl::CapsuleHandle::on (MoleDSL v2)")]
    pub fn on(&mut self, c: CapsuleId, env: Arc<dyn Environment>) -> &mut Self {
        self.set_environment(c, env);
        self
    }

    /// Plain transition (`a -- b`).
    #[deprecated(note = "use dsl::CapsuleHandle::then (MoleDSL v2)")]
    pub fn direct(&mut self, from: CapsuleId, to: CapsuleId) -> &mut Self {
        self.add_direct(from, to);
        self
    }

    /// Fan-out: run `to` once per sample of `sampling` (`a -< b`).
    #[deprecated(note = "use dsl::CapsuleHandle::explore (MoleDSL v2)")]
    pub fn explore(
        &mut self,
        from: CapsuleId,
        sampling: Arc<dyn Sampling>,
        to: CapsuleId,
    ) -> &mut Self {
        self.add_explore(from, sampling, to);
        self
    }

    /// Fan-in barrier (`b >- c`): aggregates the fan-out's results.
    #[deprecated(note = "use dsl::CapsuleHandle::aggregate (MoleDSL v2)")]
    pub fn aggregate(&mut self, from: CapsuleId, to: CapsuleId) -> &mut Self {
        self.add_aggregate(from, to);
        self
    }

    /// Set the entry capsule. Defaults to capsule 0.
    #[deprecated(note = "use dsl::CapsuleHandle::entry (MoleDSL v2)")]
    pub fn entry(&mut self, c: CapsuleId) -> &mut Self {
        self.set_entry(c);
        self
    }

    pub fn entry_capsule(&self) -> CapsuleId {
        self.entry.unwrap_or(CapsuleId(0))
    }

    pub fn outgoing(&self, c: CapsuleId) -> impl Iterator<Item = &Transition> {
        self.transitions.iter().filter(move |t| t.from() == c)
    }

    /// Terminal capsules: results arriving here are execution outputs.
    pub fn is_terminal(&self, c: CapsuleId) -> bool {
        self.outgoing(c).next().is_none()
    }

    /// How validation errors name a capsule: index plus task name.
    fn describe(&self, c: usize) -> String {
        format!("capsule {c} (`{}`)", self.capsules[c].task.name())
    }

    /// Validate shape and typed dataflow, assuming an empty initial
    /// context. Equivalent to `validate_with(&Context::new())`.
    pub fn validate(&self) -> Result<()> {
        self.validate_with(&Context::new())
    }

    /// Validate shape and typed dataflow against the initial context the
    /// execution will start with (the engine calls this from
    /// [`crate::workflow::MoleExecution::start_with`], so a mis-wired
    /// workflow is rejected before a single job is submitted).
    pub fn validate_with(&self, init: &Context) -> Result<()> {
        let order = self.validate_structure()?;
        self.validate_dataflow(init, &order)
    }

    /// Shape checks: ids in range, no cycles (iterative), all capsules
    /// reachable. Returns a topological order of the capsules.
    fn validate_structure(&self) -> Result<Vec<usize>> {
        if self.capsules.is_empty() {
            return Err(Error::InvalidWorkflow("no capsules".into()));
        }
        let n = self.capsules.len();
        for t in &self.transitions {
            if t.from().0 >= n || t.to().0 >= n {
                return Err(Error::InvalidWorkflow(format!(
                    "transition references capsule out of range ({} -> {})",
                    t.from().0,
                    t.to().0
                )));
            }
        }
        if self.entry_capsule().0 >= n {
            return Err(Error::InvalidWorkflow("entry out of range".into()));
        }

        let mut adjacency: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &self.transitions {
            adjacency[t.from().0].push(t.to().0);
        }

        // cycle detection: colored DFS with an explicit stack, so a deep
        // generated chain cannot overflow the call stack
        let mut state = vec![0u8; n]; // 0=unvisited 1=on-stack 2=done
        let mut order_rev: Vec<usize> = Vec::with_capacity(n);
        for start in 0..n {
            if state[start] != 0 {
                continue;
            }
            // (node, index of the next outgoing edge to explore)
            let mut stack: Vec<(usize, usize)> = vec![(start, 0)];
            state[start] = 1;
            while let Some(top) = stack.len().checked_sub(1) {
                let (node, next) = stack[top];
                if next < adjacency[node].len() {
                    stack[top].1 += 1;
                    let child = adjacency[node][next];
                    match state[child] {
                        0 => {
                            state[child] = 1;
                            stack.push((child, 0));
                        }
                        1 => {
                            return Err(Error::InvalidWorkflow(format!(
                                "cycle through capsule {child}"
                            )))
                        }
                        _ => {}
                    }
                } else {
                    state[node] = 2;
                    order_rev.push(node);
                    stack.pop();
                }
            }
        }

        // reachability from the entry (iterative BFS): a capsule no item
        // can ever reach is a mis-wiring, not dead weight to ignore
        let mut reachable = vec![false; n];
        let mut frontier = vec![self.entry_capsule().0];
        reachable[self.entry_capsule().0] = true;
        while let Some(u) = frontier.pop() {
            for &v in &adjacency[u] {
                if !reachable[v] {
                    reachable[v] = true;
                    frontier.push(v);
                }
            }
        }
        if let Some(c) = (0..n).find(|&c| !reachable[c]) {
            return Err(Error::InvalidWorkflow(format!(
                "{} is unreachable from the entry capsule",
                self.describe(c)
            )));
        }

        // topological order, entry-first
        let mut order: Vec<usize> = order_rev;
        order.reverse();
        Ok(order)
    }

    /// The typed dataflow pass (see module docs): walk the DAG in
    /// topological order, tracking per-capsule exploration depth and the
    /// statically known variable environment.
    fn validate_dataflow(&self, init: &Context, order: &[usize]) -> Result<()> {
        let n = self.capsules.len();
        let entry = self.entry_capsule().0;
        let mut inflow: Vec<Option<FlowEnv>> = vec![None; n];
        let mut depth: Vec<Option<i64>> = vec![None; n];
        inflow[entry] = Some(FlowEnv::from_context(init));
        depth[entry] = Some(0);

        for &u in order {
            // every capsule is reachable and predecessors precede their
            // successors in `order`, so inflow[u] is set by now
            let env_in = inflow[u]
                .take()
                .unwrap_or_else(|| FlowEnv::from_context(init));
            let d = depth[u].unwrap_or(0);
            let env_out = self.capsule_flow(u, env_in)?;

            for t in self.outgoing(CapsuleId(u)) {
                let v = t.to().0;
                let (edge_env, edge_depth) = match t {
                    Transition::Direct { .. } => (env_out.clone(), d),
                    Transition::Explore { sampling, .. } => {
                        let mut e = env_out.clone();
                        if sampling.is_columnar() {
                            for col in sampling.columns() {
                                let ty = match col.kind {
                                    ColumnKind::F64 => VarType::F64,
                                    ColumnKind::U32 => VarType::U32,
                                };
                                e.vars.insert(col.name, Some(ty));
                            }
                        } else {
                            // context-only samplings contribute variables
                            // validation cannot enumerate (and may
                            // overwrite existing ones with any type)
                            e.open_unknown();
                        }
                        (e, d + 1)
                    }
                    Transition::Aggregate { .. } => {
                        if d < 1 {
                            return Err(Error::InvalidWorkflow(format!(
                                "aggregate transition from {} has no \
                                 enclosing explore to collect",
                                self.describe(u)
                            )));
                        }
                        let e = FlowEnv {
                            vars: env_out
                                .vars
                                .iter()
                                .map(|(k, ty)| {
                                    (
                                        k.clone(),
                                        ty.clone()
                                            .map(|t| VarType::List(Box::new(t))),
                                    )
                                })
                                .collect(),
                            open: env_out.open,
                        };
                        (e, d - 1)
                    }
                };
                match depth[v] {
                    None => depth[v] = Some(edge_depth),
                    Some(prev) if prev != edge_depth => {
                        return Err(Error::InvalidWorkflow(format!(
                            "{} is reachable at inconsistent exploration \
                             depths ({prev} vs {edge_depth}) — explore and \
                             aggregate transitions do not pair up",
                            self.describe(v)
                        )))
                    }
                    Some(_) => {}
                }
                inflow[v] = Some(match inflow[v].take() {
                    None => edge_env,
                    Some(prev) => prev.intersect(&edge_env),
                });
            }
        }
        Ok(())
    }

    /// Flow one capsule: merge sources into the inflow, check the task's
    /// declared inputs against inflow ∪ sources ∪ defaults, and produce
    /// the outflow the engine will hand downstream.
    ///
    /// Defaults participate only in the *input check* (`run_checked`
    /// merges them below the context inside the task run): when a task
    /// declares outputs, its result is narrowed to exactly those, so the
    /// downstream context is inflow ∪ declared outputs — defaults never
    /// leave the capsule. A passthrough task (no declared outputs,
    /// forwards its full context) does re-emit them.
    fn capsule_flow(&self, c: usize, mut env: FlowEnv) -> Result<FlowEnv> {
        let capsule = &self.capsules[c];
        let task = capsule.task.as_ref();

        // sources merge over the incoming context (before submission)
        for source in &capsule.sources {
            match source.provides() {
                Some(specs) => {
                    for spec in specs {
                        env.vars.insert(spec.name, spec.ty);
                    }
                }
                None => env.open_unknown(),
            }
        }
        // the input check additionally sees defaults, filled below the
        // context (an upstream value keeps its type — as at runtime)
        let mut check = env.clone();
        let defaults = task.defaults();
        for name in defaults.names() {
            check
                .vars
                .entry(name.to_string())
                .or_insert_with(|| defaults.get_raw(name).and_then(Value::var_type));
        }

        for spec in task.input_specs() {
            match check.vars.get(&spec.name) {
                None => {
                    if !check.open {
                        return Err(Error::InvalidWorkflow(format!(
                            "{}: declared input `{}` is not supplied by \
                             upstream outputs, sources, sampling columns \
                             or defaults",
                            self.describe(c),
                            spec.name
                        )));
                    }
                }
                Some(Some(supplied)) => {
                    if let Some(required) = &spec.ty {
                        if !required.accepts(supplied) {
                            return Err(Error::InvalidWorkflow(format!(
                                "{}: input `{}` expects {required}, but \
                                 upstream supplies {supplied}",
                                self.describe(c),
                                spec.name
                            )));
                        }
                    }
                }
                Some(None) => {} // present, type unknown: presence is enough
            }
        }

        let outputs = task.output_specs();
        if outputs.is_empty() {
            if task.passthrough() {
                // forwards its full incoming context, defaults included
                env = check;
            } else {
                // run_checked forwards whatever the task returns —
                // anything may appear (or be overwritten) downstream
                env.open_unknown();
            }
        } else {
            // result narrowed to the declared outputs, merged over the
            // (source-injected) incoming context
            for spec in outputs {
                env.vars.insert(spec.name, spec.ty);
            }
        }
        Ok(env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_str, val_u32};
    use crate::dsl::task::{ClosureTask, IdentityTask};
    use crate::exploration::sampling::{
        ExplicitSampling, Factor, FullFactorial, SeedSampling,
    };

    fn id_task() -> Arc<dyn Task> {
        Arc::new(IdentityTask::new("id"))
    }

    #[test]
    fn builds_and_validates_linear_chain() {
        let mut p = Puzzle::new();
        let a = p.add_capsule(id_task());
        let b = p.add_capsule(id_task());
        p.add_direct(a, b);
        assert!(p.validate().is_ok());
        assert!(!p.is_terminal(a));
        assert!(p.is_terminal(b));
    }

    #[test]
    fn detects_cycles() {
        let mut p = Puzzle::new();
        let a = p.add_capsule(id_task());
        let b = p.add_capsule(id_task());
        p.add_direct(a, b);
        p.add_direct(b, a);
        assert!(p.validate().unwrap_err().to_string().contains("cycle"));
    }

    #[test]
    fn deep_chain_validates_without_stack_overflow() {
        // the historical recursive DFS overflowed on generated chains;
        // the iterative traversal must take this in stride
        let mut p = Puzzle::new();
        let mut prev = p.add_capsule(id_task());
        for _ in 0..100_000 {
            let next = p.add_capsule(id_task());
            p.add_direct(prev, next);
            prev = next;
        }
        assert!(p.validate().is_ok());
    }

    #[test]
    fn rejects_empty() {
        assert!(Puzzle::new().validate().is_err());
    }

    #[test]
    fn entry_defaults_to_first() {
        let mut p = Puzzle::new();
        let a = p.add_capsule(id_task());
        assert_eq!(p.entry_capsule(), a);
    }

    #[test]
    fn rejects_unreachable_capsules() {
        let mut p = Puzzle::new();
        let _a = p.add_capsule(id_task());
        let _stray = p.add_capsule(Arc::new(IdentityTask::new("stray")));
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("unreachable"), "{err}");
        assert!(err.contains("stray"), "names the capsule: {err}");
    }

    #[test]
    fn rejects_aggregate_without_explore() {
        let mut p = Puzzle::new();
        let a = p.add_capsule(id_task());
        let b = p.add_capsule(Arc::new(IdentityTask::new("collect")));
        p.add_aggregate(a, b);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("no enclosing explore"), "{err}");
    }

    #[test]
    fn rejects_inconsistent_exploration_depths() {
        // entry -< model and entry -- model: model items would be both
        // inside and outside the exploration
        let x = val_f64("x");
        let mut p = Puzzle::new();
        let entry = p.add_capsule(id_task());
        let model = p.add_capsule(Arc::new(IdentityTask::new("model")));
        p.add_explore(
            entry,
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 1.0, 1.0)])),
            model,
        );
        p.add_direct(entry, model);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("inconsistent exploration depths"), "{err}");
    }

    #[test]
    fn rejects_missing_input() {
        let x = val_f64("x");
        let mut p = Puzzle::new();
        p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x),
        ));
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("consumer"), "{err}");
        assert!(err.contains("`x`"), "{err}");
        assert!(err.contains("not supplied"), "{err}");
    }

    #[test]
    fn rejects_missing_input_downstream_of_declared_outputs() {
        let x = val_f64("x");
        let y = val_f64("y");
        let z = val_f64("z");
        let mut p = Puzzle::new();
        let a = p.add_capsule(Arc::new(
            ClosureTask::new("producer", {
                let y = y.clone();
                move |_| Ok(Context::new().with(&y, 1.0))
            })
            .output(&y)
            .default(&x, 0.0),
        ));
        let b = p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&z),
        ));
        p.add_direct(a, b);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("consumer") && err.contains("`z`"), "{err}");
    }

    #[test]
    fn defaults_do_not_leak_downstream_of_declared_outputs() {
        // A's default for `x` exists only inside A's run (run_checked
        // narrows A's result to its declared outputs), so B's `x` input
        // is genuinely unsupplied — the old pass wrongly accepted this
        let x = val_f64("x");
        let y = val_f64("y");
        let mut p = Puzzle::new();
        let a = p.add_capsule(Arc::new(
            ClosureTask::new("producer", {
                let y = y.clone();
                move |_| Ok(Context::new().with(&y, 1.0))
            })
            .default(&x, 0.0)
            .output(&y),
        ));
        let b = p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x),
        ));
        p.add_direct(a, b);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("consumer") && err.contains("`x`"), "{err}");
    }

    #[test]
    fn own_defaults_satisfy_inputs_despite_upstream_type() {
        // B defaults `x` itself — but an upstream f64 `x` wins at runtime
        // (context over defaults), so the string-typed input is still a
        // genuine mismatch; with no upstream supply it validates fine
        let x = val_f64("x");
        let x_str = val_str("x");
        let y = val_f64("y");
        let consumer = || {
            ClosureTask::new("consumer", |_| Ok(Context::new()))
                .default(&x_str, "label".into())
                .input(&x_str)
        };

        let mut standalone = Puzzle::new();
        standalone.add_capsule(Arc::new(consumer()));
        assert!(standalone.validate().is_ok(), "own default supplies x");

        let mut fed = Puzzle::new();
        let a = fed.add_capsule(Arc::new(
            ClosureTask::new("producer", {
                let (x, y) = (x.clone(), y.clone());
                move |_| Ok(Context::new().with(&x, 1.0).with(&y, 1.0))
            })
            .output(&x)
            .output(&y),
        ));
        let b = fed.add_capsule(Arc::new(consumer()));
        fed.add_direct(a, b);
        let err = fed.validate().unwrap_err().to_string();
        assert!(err.contains("expects string"), "{err}");
    }

    #[test]
    fn rejects_type_mismatch() {
        let x = val_f64("x");
        let x_str = val_str("x");
        let mut p = Puzzle::new();
        let a = p.add_capsule(Arc::new(
            ClosureTask::new("producer", {
                let x = x.clone();
                move |_| Ok(Context::new().with(&x, 1.0))
            })
            .output(&x),
        ));
        let b = p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x_str),
        ));
        p.add_direct(a, b);
        let err = p.validate().unwrap_err().to_string();
        assert!(err.contains("expects string"), "{err}");
        assert!(err.contains("supplies f64"), "{err}");
    }

    #[test]
    fn accepts_numeric_widening_and_sampling_columns() {
        // seed column (u32) feeds a u32 input AND an f64 input
        let seed = val_u32("seed");
        let wide = val_f64("seed");
        let mut p = Puzzle::new();
        let entry = p.add_capsule(id_task());
        let a = p.add_capsule(Arc::new(
            ClosureTask::new("narrow", |_| Ok(Context::new())).input(&seed),
        ));
        let b = p.add_capsule(Arc::new(
            ClosureTask::new("wide", |_| Ok(Context::new())).input(&wide),
        ));
        p.add_explore(entry, Arc::new(SeedSampling::new(&seed, 3)), a);
        p.add_direct(a, b);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn aggregate_produces_list_types() {
        use crate::exploration::statistics::StatisticTask;
        use crate::util::stats::Descriptor;
        let x = val_f64("x");
        let y = val_f64("y");
        let m = val_f64("m");
        let mut p = Puzzle::new();
        let entry = p.add_capsule(id_task());
        let model = p.add_capsule(Arc::new(
            ClosureTask::new("sq", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y),
        ));
        let stat = p.add_capsule(Arc::new(
            StatisticTask::new().statistic(&y, &m, Descriptor::Median),
        ));
        p.add_explore(
            entry,
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 3.0, 1.0)])),
            model,
        );
        p.add_aggregate(model, stat);
        assert!(p.validate().is_ok());

        // and a scalar consumer of the aggregated variable is a mismatch
        let mut p2 = Puzzle::new();
        let entry = p2.add_capsule(id_task());
        let model = p2.add_capsule(Arc::new(
            ClosureTask::new("sq", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y),
        ));
        let scalar = p2.add_capsule(Arc::new(
            ClosureTask::new("scalar", |_| Ok(Context::new())).input(&y),
        ));
        p2.add_explore(
            entry,
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 3.0, 1.0)])),
            model,
        );
        p2.add_aggregate(model, scalar);
        let err = p2.validate().unwrap_err().to_string();
        assert!(err.contains("expects f64") && err.contains("list<f64>"), "{err}");
    }

    #[test]
    fn open_flow_demotes_known_types_instead_of_inventing_mismatches() {
        // producer (f64 x) -> middle with UNDECLARED outputs that re-emits
        // x as a string -> consumer expecting string x. At runtime the
        // middle task's unfiltered result wins the merge, so this runs —
        // validation must not reject it on the stale f64 type.
        let x = val_f64("x");
        let x_str = val_str("x");
        let mut p = Puzzle::new();
        let a = p.add_capsule(Arc::new(
            ClosureTask::new("producer", {
                let x = x.clone();
                move |_| Ok(Context::new().with(&x, 1.0))
            })
            .output(&x),
        ));
        let mid = p.add_capsule(Arc::new(ClosureTask::new("relabel", {
            let x_str = x_str.clone();
            move |_| Ok(Context::new().with(&x_str, "label".to_string()))
        })));
        let b = p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x_str),
        ));
        p.add_direct(a, mid);
        p.add_direct(mid, b);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn context_only_sampling_opens_the_flow() {
        // downstream of an ExplicitSampling the checker cannot enumerate
        // variables, so missing-input errors must be suppressed
        let x = val_f64("x");
        let mut p = Puzzle::new();
        let entry = p.add_capsule(id_task());
        let model = p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x),
        ));
        p.add_explore(
            entry,
            Arc::new(ExplicitSampling::new(vec![Context::new().with(&x, 1.0)])),
            model,
        );
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_with_initial_context_supplies_inputs() {
        let x = val_f64("x");
        let mut p = Puzzle::new();
        p.add_capsule(Arc::new(
            ClosureTask::new("consumer", |_| Ok(Context::new())).input(&x),
        ));
        assert!(p.validate().is_err(), "bare validate has no x");
        assert!(p.validate_with(&Context::new().with(&x, 2.0)).is_ok());
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_v1_mutators_still_work() {
        let mut p = Puzzle::new();
        let a = p.capsule(id_task());
        let b = p.capsule(id_task());
        p.direct(a, b);
        p.entry(a);
        assert!(p.validate().is_ok());
    }
}
