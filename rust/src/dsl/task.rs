//! Tasks: the unit of computation a workflow delegates (paper §2.1).
//!
//! Tasks are deliberately side-effect free ("mute pieces of software" —
//! §4.3): they compute outputs from inputs, which is what makes them safe
//! to delegate to remote environments. All observable effects go through
//! hooks.

use std::sync::Arc;

use crate::core::{Context, Val, VarSpec, ValueType};
use crate::error::{Error, Result};

/// The unit of delegated computation.
pub trait Task: Send + Sync {
    fn name(&self) -> &str;

    /// Declared input variable names (presence is validated before run).
    /// The default derives the names from [`Task::input_specs`]; typed
    /// tasks only implement the spec form.
    fn inputs(&self) -> Vec<String> {
        self.input_specs().into_iter().map(|s| s.name).collect()
    }

    /// Declared output variable names (the engine narrows the returned
    /// context to these, so undeclared writes never leak downstream).
    /// The default derives the names from [`Task::output_specs`].
    fn outputs(&self) -> Vec<String> {
        self.output_specs().into_iter().map(|s| s.name).collect()
    }

    /// Typed input interface (MoleDSL v2): name + static type of every
    /// declared input. [`crate::dsl::Puzzle::validate`] proves each one
    /// is supplied — with a compatible type — by upstream outputs,
    /// sources, sampling columns or defaults, before any job is
    /// submitted.
    fn input_specs(&self) -> Vec<VarSpec> {
        Vec::new()
    }

    /// Typed output interface (MoleDSL v2), the supply side of the
    /// build-time dataflow check.
    fn output_specs(&self) -> Vec<VarSpec> {
        Vec::new()
    }

    /// True for tasks that forward their incoming context unchanged
    /// (entry/exit anchors). Lets validation keep precise knowledge of
    /// the dataflow through a capsule with no declared outputs instead
    /// of assuming it may emit anything.
    fn passthrough(&self) -> bool {
        false
    }

    /// Default values, merged below the incoming context.
    fn defaults(&self) -> Context {
        Context::new()
    }

    /// Execute. Must be deterministic given the context (stochasticity
    /// enters via explicit seed variables).
    fn run(&self, ctx: &Context) -> Result<Context>;

    /// Hint for simulated environments: the nominal execution cost of one
    /// run, in seconds of *remote core time*. Used by the cluster/grid
    /// simulators to schedule virtual time (the real computation still
    /// runs locally). Defaults to 1s, the order of one NetLogo ant run.
    fn cost_hint(&self) -> f64 {
        1.0
    }
}

/// Validate inputs, merge defaults, run, narrow outputs.
///
/// This is the single entry point every environment uses to execute a task,
/// so declared-interface enforcement is uniform across local and simulated
/// remote execution.
pub fn run_checked(task: &dyn Task, ctx: &Context) -> Result<Context> {
    let mut full = task.defaults();
    full.merge(ctx);
    for input in task.inputs() {
        if !full.contains(&input) {
            return Err(Error::TaskFailed {
                task: task.name().to_string(),
                message: format!("missing declared input `{input}`"),
            });
        }
    }
    let out = task.run(&full)?;
    let outputs = task.outputs();
    if outputs.is_empty() {
        return Ok(out);
    }
    for o in &outputs {
        if !out.contains(o) {
            return Err(Error::TaskFailed {
                task: task.name().to_string(),
                message: format!("declared output `{o}` was not produced"),
            });
        }
    }
    let names: Vec<&str> = outputs.iter().map(String::as_str).collect();
    Ok(out.filtered(&names))
}

type Body = dyn Fn(&Context) -> Result<Context> + Send + Sync;

/// The `ScalaTask` analogue: a task defined by an inline closure.
pub struct ClosureTask {
    name: String,
    inputs: Vec<VarSpec>,
    outputs: Vec<VarSpec>,
    defaults: Context,
    cost_hint: f64,
    body: Arc<Body>,
}

impl ClosureTask {
    pub fn new(
        name: impl Into<String>,
        body: impl Fn(&Context) -> Result<Context> + Send + Sync + 'static,
    ) -> Self {
        ClosureTask {
            name: name.into(),
            inputs: Vec::new(),
            outputs: Vec::new(),
            defaults: Context::new(),
            cost_hint: 1.0,
            body: Arc::new(body),
        }
    }

    /// Declare an input prototype (name and type enter the build-time
    /// wiring check).
    pub fn input<T: ValueType>(mut self, v: &Val<T>) -> Self {
        self.inputs.push(VarSpec::typed(v));
        self
    }

    /// Declare an output prototype.
    pub fn output<T: ValueType>(mut self, v: &Val<T>) -> Self {
        self.outputs.push(VarSpec::typed(v));
        self
    }

    /// Provide a default value (the `:=` of the DSL).
    pub fn default<T: ValueType>(mut self, v: &Val<T>, value: T) -> Self {
        self.defaults.set(v, value);
        self
    }

    /// Set the simulated-cost hint (seconds of remote core time).
    pub fn cost(mut self, seconds: f64) -> Self {
        self.cost_hint = seconds;
        self
    }
}

impl Task for ClosureTask {
    fn name(&self) -> &str {
        &self.name
    }
    fn input_specs(&self) -> Vec<VarSpec> {
        self.inputs.clone()
    }
    fn output_specs(&self) -> Vec<VarSpec> {
        self.outputs.clone()
    }
    fn defaults(&self) -> Context {
        self.defaults.clone()
    }
    fn cost_hint(&self) -> f64 {
        self.cost_hint
    }
    fn run(&self, ctx: &Context) -> Result<Context> {
        (self.body)(ctx)
    }
}

/// A task that simply copies selected variables through — useful as an
/// entry/exit anchor in puzzles.
pub struct IdentityTask {
    name: String,
}

impl IdentityTask {
    pub fn new(name: impl Into<String>) -> Self {
        IdentityTask { name: name.into() }
    }
}

impl Task for IdentityTask {
    fn name(&self) -> &str {
        &self.name
    }
    fn run(&self, ctx: &Context) -> Result<Context> {
        Ok(ctx.clone())
    }
    fn passthrough(&self) -> bool {
        true
    }
    fn cost_hint(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    #[test]
    fn closure_task_runs() {
        let x = val_f64("x");
        let y = val_f64("y");
        let t = ClosureTask::new("double", {
            let (x, y) = (x.clone(), y.clone());
            move |ctx| {
                let v = ctx.get(&x)?;
                Ok(Context::new().with(&y, v * 2.0))
            }
        })
        .input(&x)
        .output(&y);
        let out = run_checked(&t, &Context::new().with(&x, 3.0)).unwrap();
        assert_eq!(out.get(&y).unwrap(), 6.0);
    }

    #[test]
    fn missing_input_fails_before_run() {
        let x = val_f64("x");
        let t = ClosureTask::new("t", |_| Ok(Context::new())).input(&x);
        let err = run_checked(&t, &Context::new()).unwrap_err();
        assert!(err.to_string().contains("missing declared input"));
    }

    #[test]
    fn defaults_fill_missing_inputs() {
        let x = val_f64("x");
        let t = ClosureTask::new("t", {
            let x = x.clone();
            move |ctx| Ok(Context::new().with(&x, ctx.get(&x)? + 1.0))
        })
        .input(&x)
        .default(&x, 41.0)
        .output(&x);
        let out = run_checked(&t, &Context::new()).unwrap();
        assert_eq!(out.get(&x).unwrap(), 42.0);
    }

    #[test]
    fn incoming_context_overrides_defaults() {
        let x = val_f64("x");
        let t = ClosureTask::new("t", {
            let x = x.clone();
            move |ctx| Ok(ctx.clone().with(&x, ctx.get(&x)?))
        })
        .input(&x)
        .default(&x, 1.0)
        .output(&x);
        let out = run_checked(&t, &Context::new().with(&x, 9.0)).unwrap();
        assert_eq!(out.get(&x).unwrap(), 9.0);
    }

    #[test]
    fn outputs_are_narrowed() {
        let x = val_f64("x");
        let y = val_f64("y");
        let t = ClosureTask::new("t", {
            let (x, y) = (x.clone(), y.clone());
            move |_| Ok(Context::new().with(&x, 1.0).with(&y, 2.0))
        })
        .output(&y);
        let out = run_checked(&t, &Context::new()).unwrap();
        assert!(!out.contains("x"), "undeclared output leaked");
        assert_eq!(out.get(&y).unwrap(), 2.0);
    }

    #[test]
    fn undeclared_output_is_error() {
        let y = val_f64("y");
        let t = ClosureTask::new("t", |_| Ok(Context::new())).output(&y);
        assert!(run_checked(&t, &Context::new()).is_err());
    }
}
