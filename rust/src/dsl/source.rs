//! Sources — the input half of §2.1's dataflow facilities: "OpenMOLE
//! exposes several facilities to inject data in the dataflow (sources)
//! and extract useful results at the end of the experiment (hooks)".
//!
//! A source runs on the coordinator just before a capsule's task and
//! merges variables into its incoming context.

use std::path::PathBuf;

use crate::core::{Context, Val, Value, ValueType, VarSpec, VarType};
use crate::error::{Error, Result};

/// Injects variables into a capsule's incoming context.
pub trait Source: Send + Sync {
    fn name(&self) -> &str;
    /// Produce the variables to merge (the incoming context is provided
    /// for sources parameterised by upstream data).
    fn inject(&self, incoming: &Context) -> Result<Context>;
    /// Declared contribution for build-time wiring validation: the
    /// variables [`Source::inject`] will merge, when they are known
    /// without running it. `None` means the contribution cannot be
    /// declared — validation then treats the capsule's inflow as open
    /// (missing-input errors are suppressed, never invented).
    fn provides(&self) -> Option<Vec<VarSpec>> {
        None
    }
}

/// Fixed-value source (`ConstantSource` — e.g. experiment constants).
pub struct ConstantSource {
    values: Context,
}

impl ConstantSource {
    pub fn new() -> Self {
        ConstantSource {
            values: Context::new(),
        }
    }

    pub fn with<T: ValueType>(mut self, v: &Val<T>, value: T) -> Self {
        self.values.set(v, value);
        self
    }
}

impl Default for ConstantSource {
    fn default() -> Self {
        Self::new()
    }
}

impl Source for ConstantSource {
    fn name(&self) -> &str {
        "ConstantSource"
    }

    fn inject(&self, _incoming: &Context) -> Result<Context> {
        Ok(self.values.clone())
    }

    fn provides(&self) -> Option<Vec<VarSpec>> {
        Some(
            self.values
                .names()
                .map(|n| VarSpec {
                    name: n.to_string(),
                    ty: self.values.get_raw(n).and_then(Value::var_type),
                })
                .collect(),
        )
    }
}

/// CSV file source: reads numeric columns into `Vec<f64>` variables (the
/// `CSVSource` of the OpenMOLE DSL). The header row names the columns;
/// each requested column becomes an array variable of the same name.
pub struct CsvSource {
    path: PathBuf,
    columns: Vec<String>,
}

impl CsvSource {
    pub fn new(path: impl Into<PathBuf>, columns: &[&str]) -> Self {
        CsvSource {
            path: path.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        }
    }
}

impl Source for CsvSource {
    fn name(&self) -> &str {
        "CsvSource"
    }

    fn provides(&self) -> Option<Vec<VarSpec>> {
        // each requested column materialises as an array variable
        Some(
            self.columns
                .iter()
                .map(|c| VarSpec::of(c, VarType::List(Box::new(VarType::F64))))
                .collect(),
        )
    }

    fn inject(&self, _incoming: &Context) -> Result<Context> {
        let text = std::fs::read_to_string(&self.path).map_err(|e| {
            Error::TaskFailed {
                task: "CsvSource".into(),
                message: format!("cannot read {}: {e}", self.path.display()),
            }
        })?;
        let mut lines = text.lines();
        let header: Vec<&str> = lines
            .next()
            .ok_or_else(|| Error::TaskFailed {
                task: "CsvSource".into(),
                message: "empty csv".into(),
            })?
            .split(',')
            .map(str::trim)
            .collect();
        let mut cols: Vec<(usize, Vec<f64>)> = Vec::new();
        for want in &self.columns {
            let idx = header.iter().position(|h| h == want).ok_or_else(|| {
                Error::TaskFailed {
                    task: "CsvSource".into(),
                    message: format!("column `{want}` not in header {header:?}"),
                }
            })?;
            cols.push((idx, Vec::new()));
        }
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').map(str::trim).collect();
            for (idx, values) in &mut cols {
                let cell = fields.get(*idx).copied().unwrap_or("");
                let v: f64 = cell.parse().map_err(|_| Error::TaskFailed {
                    task: "CsvSource".into(),
                    message: format!("row {}: `{cell}` is not numeric", lineno + 2),
                })?;
                values.push(v);
            }
        }
        let mut out = Context::new();
        for (name, (_, values)) in self.columns.iter().zip(cols) {
            out.set_raw(
                name,
                Value::List(values.into_iter().map(Value::F64).collect()),
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    #[test]
    fn constant_source_injects() {
        let x = val_f64("x");
        let s = ConstantSource::new().with(&x, 9.0);
        let ctx = s.inject(&Context::new()).unwrap();
        assert_eq!(ctx.get(&x).unwrap(), 9.0);
    }

    #[test]
    fn csv_source_reads_columns() {
        let path = std::env::temp_dir().join(format!("molers-src-{}.csv", std::process::id()));
        std::fs::write(&path, "a,b,c\n1,2,3\n4,5,6\n").unwrap();
        let s = CsvSource::new(&path, &["a", "c"]);
        let ctx = s.inject(&Context::new()).unwrap();
        let a = val_f64("a");
        let c = val_f64("c");
        assert_eq!(ctx.get(&a.array()).unwrap(), vec![1.0, 4.0]);
        assert_eq!(ctx.get(&c.array()).unwrap(), vec![3.0, 6.0]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn csv_source_errors_are_descriptive() {
        let path = std::env::temp_dir().join(format!("molers-src2-{}.csv", std::process::id()));
        std::fs::write(&path, "a,b\n1,notanumber\n").unwrap();
        let s = CsvSource::new(&path, &["b"]);
        let err = s.inject(&Context::new()).unwrap_err();
        assert!(err.to_string().contains("not numeric"));
        let missing = CsvSource::new(&path, &["zzz"]);
        assert!(missing
            .inject(&Context::new())
            .unwrap_err()
            .to_string()
            .contains("not in header"));
        let _ = std::fs::remove_file(&path);
    }
}
