//! The workflow DSL: tasks, hooks, capsules, transitions, puzzles.
//!
//! Mirrors the vocabulary of OpenMOLE's Scala DSL (paper §2.1) with Rust
//! builders: `ClosureTask` ≈ `ScalaTask`, [`CapsuleHandle::on`] ≈
//! `task on env`, [`CapsuleHandle::hook`] ≈ `task hook h`,
//! [`CapsuleHandle::then`]/[`CapsuleHandle::explore`]/
//! [`CapsuleHandle::aggregate`] ≈ `a -- b`, `a -< b`, `b >- c`
//! (MoleDSL v2 — see [`builder`]).

pub mod builder;
pub mod hook;
pub mod puzzle;
pub mod source;
pub mod system_exec;
pub mod task;

pub use builder::{CapsuleHandle, PuzzleBuilder};
pub use hook::{
    CaptureHook, ColumnSummary, CsvHook, DisplayHook, Hook, RowWriter, Sink,
    TableFormat, ToStringHook,
};
pub use puzzle::{Capsule, CapsuleId, Puzzle, Transition};
pub use source::{ConstantSource, CsvSource, Source};
pub use system_exec::SystemExecTask;
pub use task::{ClosureTask, IdentityTask, Task};
