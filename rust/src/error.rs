//! Unified error type for the molers crate.

use thiserror::Error;

/// Errors surfaced by the workflow engine and its substrates.
#[derive(Error, Debug)]
pub enum Error {
    /// A task read a variable that is absent from its input context.
    #[error("missing variable `{0}` in context")]
    MissingVariable(String),

    /// A variable existed but held a different type than requested.
    #[error("variable `{name}` has type {actual}, expected {expected}")]
    TypeMismatch {
        name: String,
        expected: &'static str,
        actual: &'static str,
    },

    /// Workflow graph is malformed (cycle, dangling transition, ...).
    #[error("invalid workflow: {0}")]
    InvalidWorkflow(String),

    /// A task body failed.
    #[error("task `{task}` failed: {message}")]
    TaskFailed { task: String, message: String },

    /// Job submission / polling failure on an execution environment.
    #[error("environment `{environment}` error: {message}")]
    EnvironmentError {
        environment: String,
        message: String,
    },

    /// A job exceeded its wall time and was killed by the scheduler.
    #[error("job killed after exceeding wall time ({0} s of simulated time)")]
    WallTimeExceeded(u64),

    /// A job failed on a remote node (simulated infrastructure fault).
    #[error("job failed on node `{node}`: {reason}")]
    NodeFailure { node: String, reason: String },

    /// Packaging / re-execution failure (CARE/CDE substrate).
    #[error("packaging error: {0}")]
    Packaging(String),

    /// The PJRT runtime failed to load or execute an artifact.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// artifacts/manifest.json was missing or malformed.
    #[error("artifact manifest error: {0}")]
    Manifest(String),

    /// Evolution configuration error (bounds, population sizes, ...).
    #[error("evolution error: {0}")]
    Evolution(String),

    /// GridScale command construction/parsing error.
    #[error("gridscale error: {0}")]
    GridScale(String),

    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Wrapped error from the `xla` crate (PJRT layer).
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
