//! Unified error type for the molers crate.
//!
//! Hand-rolled `Display`/`Error` impls: the `thiserror` crate is not
//! vendored in this image (DESIGN.md §3), and the error surface is small
//! enough that the derive buys nothing.

use std::fmt;

/// Errors surfaced by the workflow engine and its substrates.
#[derive(Debug)]
pub enum Error {
    /// A task read a variable that is absent from its input context.
    MissingVariable(String),

    /// A variable existed but held a different type than requested.
    TypeMismatch {
        name: String,
        expected: &'static str,
        actual: &'static str,
    },

    /// Workflow graph is malformed (cycle, dangling transition, mis-typed
    /// or unsupplied dataflow, ...).
    InvalidWorkflow(String),

    /// Experiment/CLI configuration error (bad flag value, unknown
    /// environment, `--resume` journal mismatch, ...). Displayed verbatim.
    Config(String),

    /// A task body failed.
    TaskFailed { task: String, message: String },

    /// Job submission / polling failure on an execution environment.
    EnvironmentError {
        environment: String,
        message: String,
    },

    /// A job exceeded its wall time and was killed by the scheduler.
    WallTimeExceeded(u64),

    /// A job failed on a remote node (simulated infrastructure fault).
    NodeFailure { node: String, reason: String },

    /// A broker-enforced **real-time** bound expired: the attempt (or the
    /// whole job) was abandoned as hung. `what` is `"attempt timeout"` or
    /// `"job deadline"`.
    Timeout {
        environment: String,
        what: &'static str,
        after_s: f64,
    },

    /// Packaging / re-execution failure (CARE/CDE substrate).
    Packaging(String),

    /// The PJRT runtime failed to load or execute an artifact.
    Runtime(String),

    /// artifacts/manifest.json was missing or malformed.
    Manifest(String),

    /// Evolution configuration error (bounds, population sizes, ...).
    Evolution(String),

    /// GridScale command construction/parsing error.
    GridScale(String),

    /// A provenance check failed (`molers reexec`): a tampered result
    /// file, a digest that does not reproduce, a mismatched env fleet or
    /// build. `kind` is a stable machine-matchable label; the check must
    /// fail **loudly and named**, never degrade to a generic error.
    Provenance { kind: &'static str, message: String },

    Json { offset: usize, message: String },

    Io(std::io::Error),

    /// Wrapped error from the xla PJRT layer.
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::MissingVariable(name) => {
                write!(f, "missing variable `{name}` in context")
            }
            Error::TypeMismatch {
                name,
                expected,
                actual,
            } => write!(
                f,
                "variable `{name}` has type {actual}, expected {expected}"
            ),
            Error::InvalidWorkflow(msg) => write!(f, "invalid workflow: {msg}"),
            Error::Config(msg) => write!(f, "{msg}"),
            Error::TaskFailed { task, message } => {
                write!(f, "task `{task}` failed: {message}")
            }
            Error::EnvironmentError {
                environment,
                message,
            } => write!(f, "environment `{environment}` error: {message}"),
            Error::WallTimeExceeded(s) => write!(
                f,
                "job killed after exceeding wall time ({s} s of simulated time)"
            ),
            Error::NodeFailure { node, reason } => {
                write!(f, "job failed on node `{node}`: {reason}")
            }
            Error::Timeout {
                environment,
                what,
                after_s,
            } => write!(
                f,
                "{what} of {after_s:.0} s exceeded on `{environment}`: job abandoned as hung"
            ),
            Error::Packaging(msg) => write!(f, "packaging error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Manifest(msg) => write!(f, "artifact manifest error: {msg}"),
            Error::Evolution(msg) => write!(f, "evolution error: {msg}"),
            Error::GridScale(msg) => write!(f, "gridscale error: {msg}"),
            Error::Provenance { kind, message } => {
                write!(f, "provenance error [{kind}]: {message}")
            }
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(msg) => write!(f, "xla: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

use crate::runtime::xla_stub as xla;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_previous_derive_format() {
        assert_eq!(
            Error::MissingVariable("x".into()).to_string(),
            "missing variable `x` in context"
        );
        assert_eq!(
            Error::TypeMismatch {
                name: "x".into(),
                expected: "f64",
                actual: "i64",
            }
            .to_string(),
            "variable `x` has type i64, expected f64"
        );
        assert_eq!(
            Error::TaskFailed {
                task: "t".into(),
                message: "boom".into(),
            }
            .to_string(),
            "task `t` failed: boom"
        );
        assert_eq!(
            Error::Json {
                offset: 3,
                message: "bad".into()
            }
            .to_string(),
            "json parse error at byte 3: bad"
        );
    }

    #[test]
    fn provenance_errors_are_named() {
        let e = Error::Provenance {
            kind: "result-tampered",
            message: "digest mismatch on `out.csv`".into(),
        };
        // the kind label is part of the display contract: scripts (and
        // the CI acceptance step) grep for it
        assert_eq!(
            e.to_string(),
            "provenance error [result-tampered]: digest mismatch on `out.csv`"
        );
    }

    #[test]
    fn io_error_is_transparent_and_sourced() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let msg = io.to_string();
        let e: Error = io.into();
        assert_eq!(e.to_string(), msg);
        assert!(std::error::Error::source(&e).is_some());
    }
}
