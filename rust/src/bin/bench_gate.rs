//! Bench-regression gate (§Perf CI satellite): compare freshly
//! regenerated `BENCH_*.json` acceptance metrics against the committed
//! baselines with a **generous** tolerance, print a before/after table
//! (GitHub-flavoured markdown — CI appends it to the job summary), and
//! exit non-zero on regression.
//!
//! ```text
//! bench_gate --baseline-dir . --new-dir bench-out
//! ```
//!
//! Tolerances are deliberately loose: CI runs the benches in reduced mode
//! on noisy shared runners, so the gate only catches *structural*
//! regressions (a speedup collapsing to serial, the columnar wave
//! re-growing an O(N) allocation pattern), never a few percent of jitter.
//! Ratio metrics (speedups) are scale-independent and must stay above
//! `tolerance × baseline`; time metrics must stay below
//! `tolerance × baseline` (trivially true in reduced mode, load-bearing
//! for full-scale local runs).

use std::path::Path;
use std::process::ExitCode;

use molers::cli::Args;
use molers::util::json::{parse, Json};

/// One gated metric.
struct Check {
    suite: &'static str,
    metric: &'static str,
    /// `true`: fail when `new < tolerance * baseline` (speedup-like).
    /// `false`: fail when `new > tolerance * baseline` (time-like).
    higher_is_better: bool,
    tolerance: f64,
}

const CHECKS: &[Check] = &[
    Check {
        suite: "p1_evaluator",
        metric: "p1_evaluator/batch32_pool_speedup",
        higher_is_better: true,
        tolerance: 0.5,
    },
    Check {
        suite: "p3_broker",
        metric: "p3_broker/failing20_rr_over_ewma",
        higher_is_better: true,
        tolerance: 0.5,
    },
    Check {
        suite: "p2_scale",
        metric: "p2_scale/full_wave_s",
        higher_is_better: false,
        tolerance: 2.0,
    },
    // scale-independent structural gate: the committed baseline is 0, so
    // `new <= 2.0 * 0` demands exactly zero steady-state allocations at
    // ANY wave size — this is the check that actually bites in CI's
    // reduced mode, where the full_wave_s time bound (committed at 200k,
    // regenerated at N=5000) is trivially satisfied and only becomes
    // load-bearing for full-scale local runs.
    Check {
        suite: "p2_scale",
        metric: "p2_scale/wave_reuse_allocations",
        higher_is_better: false,
        tolerance: 2.0,
    },
    Check {
        suite: "p4_explore",
        metric: "p4_explore/explore_wave_s",
        higher_is_better: false,
        tolerance: 2.0,
    },
    // like wave_reuse_allocations: baseline 0, so the bound is exactly
    // zero steady-state allocations at any design size — the structural
    // §Exploration claim, load-bearing even in CI's reduced mode
    Check {
        suite: "p4_explore",
        metric: "p4_explore/explore_wave_allocations",
        higher_is_better: false,
        tolerance: 2.0,
    },
    // scale-independent ratio (wrapped/bare wall time of the same wave,
    // measured back-to-back in one process): an empty-FaultPlan decorator
    // on the submission path must stay within 10% of free — the
    // §Robustness acceptance, tight on purpose
    Check {
        suite: "p5_chaos",
        metric: "p5_chaos/chaos_overhead",
        higher_is_better: false,
        tolerance: 1.1,
    },
    // scale-independent ratio (always/os wall time of the same journaled
    // sweep, measured back-to-back in one process): fsync-per-checkpoint
    // durability amortizes over chunk evaluation and must stay within 3×
    // of the flush-only policy — the §Durability acceptance (baseline
    // 1.17 × tolerance 2.5 keeps the effective bound under 3×)
    Check {
        suite: "p6_durability",
        metric: "p6_durability/fsync_overhead",
        higher_is_better: false,
        tolerance: 2.5,
    },
    // scale-independent ratio (spilled/in-RAM wall time of the same
    // streaming wave, measured back-to-back in one process): chunk-paged
    // spilling must stay within 1.5× of contiguous RAM — the §Out-of-core
    // acceptance (baseline 1.0 × tolerance 1.5)
    Check {
        suite: "p7_outofcore",
        metric: "p7_outofcore/spill_overhead",
        higher_is_better: false,
        tolerance: 1.5,
    },
    // baseline 0, so the bound is exactly zero steady-state allocations
    // at any design size or budget: the arena-recycled spill path never
    // allocates per wave — load-bearing even in CI's reduced mode
    Check {
        suite: "p7_outofcore",
        metric: "p7_outofcore/spill_wave_allocations",
        higher_is_better: false,
        tolerance: 2.0,
    },
    // scale-independent ratio (one-lane replay / direct sequential wall
    // time of the same seeded trace, measured back-to-back in one
    // process): the workload replay harness — broker fleet + fair-share
    // gate + lane thread + pacing — is bookkeeping over the experiments
    // themselves and must stay within 1.5× of running them directly
    // (baseline 1.07 × tolerance 1.5 keeps the effective bound ~1.6×)
    Check {
        suite: "p8_workload",
        metric: "p8_workload/replay_overhead",
        higher_is_better: false,
        tolerance: 1.5,
    },
];

fn load_suite(dir: &Path, suite: &str) -> Option<Json> {
    let path = dir.join(format!("BENCH_{suite}.json"));
    let text = std::fs::read_to_string(path).ok()?;
    parse(&text).ok()
}

fn metric_value(doc: &Json, name: &str) -> Option<f64> {
    for m in doc.get("metrics")?.as_arr()? {
        if m.get("name").and_then(Json::as_str) == Some(name) {
            return m.get("value").and_then(Json::as_f64);
        }
    }
    None
}

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            return ExitCode::from(2);
        }
    };
    let baseline_dir = Path::new(args.get_or("baseline-dir", "."));
    let new_dir = Path::new(args.get_or("new-dir", "bench-out"));

    println!("## Bench regression gate");
    println!();
    println!("baselines: `{}` · regenerated: `{}`", baseline_dir.display(), new_dir.display());
    println!();
    println!("| metric | baseline | regenerated | bound | status |");
    println!("|---|---:|---:|---|---|");

    let mut failed = false;
    for check in CHECKS {
        let baseline = load_suite(baseline_dir, check.suite)
            .as_ref()
            .and_then(|d| metric_value(d, check.metric));
        let fresh = load_suite(new_dir, check.suite)
            .as_ref()
            .and_then(|d| metric_value(d, check.metric));
        let (status, bound) = match (baseline, fresh) {
            (Some(base), Some(new)) => {
                let bound = check.tolerance * base;
                let ok = if check.higher_is_better {
                    new >= bound
                } else {
                    new <= bound
                };
                let rel = if check.higher_is_better { "≥" } else { "≤" };
                failed |= !ok;
                (
                    if ok { "✅ ok" } else { "❌ REGRESSION" },
                    format!("{rel} {bound:.4}"),
                )
            }
            (None, _) => {
                // no committed baseline: informational only, never fatal
                ("➖ no baseline", String::from("—"))
            }
            (Some(_), None) => {
                failed = true;
                ("❌ metric missing from regenerated run", String::from("—"))
            }
        };
        let fmt = |v: Option<f64>| v.map_or_else(|| String::from("—"), |v| format!("{v:.4}"));
        println!(
            "| `{}` | {} | {} | {} | {} |",
            check.metric,
            fmt(baseline),
            fmt(fresh),
            bound,
            status
        );
    }
    println!();
    if failed {
        println!("**Gate failed** — a gated metric regressed past its tolerance.");
        ExitCode::FAILURE
    } else {
        println!("Gate passed.");
        ExitCode::SUCCESS
    }
}
