//! Request parsing and response building for the JSONL wire protocol
//! (grammar in the [module docs](crate::serve)). One JSON object per
//! line, both directions; responses always carry an `ok` field.

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Default listen address. `--addr 127.0.0.1:0` binds an ephemeral port
/// (written to `<state-dir>/addr` for scripts to discover).
pub const DEFAULT_ADDR: &str = "127.0.0.1:4268";

/// One parsed request line.
#[derive(Debug, Clone)]
pub struct Request {
    pub cmd: String,
    /// Experiment id, for `status`/`watch`/`cancel`/`result`.
    pub id: Option<u64>,
    /// Fair-share tenant name (`"default"` when absent).
    pub tenant: String,
    /// Fair-share weight (clamped to ≥ 1).
    pub weight: u64,
    /// Method name for `submit` (`run|explore|replicate|calibrate|island`).
    pub run: Option<String>,
    /// Method CLI options, key → value (non-string values are allowed on
    /// the wire and stringified).
    pub options: Vec<(String, String)>,
    /// Method CLI flags.
    pub flags: Vec<String>,
    /// Client-supplied idempotency key for `submit`: retrying a submit
    /// whose response was lost returns the original experiment id
    /// instead of double-running. Scoped per tenant.
    pub dedup_key: Option<String>,
    /// For `watch`: replay buffered events with `seq` strictly greater
    /// than this before streaming live ones (reconnect resume point).
    pub after_seq: Option<u64>,
}

/// Parse one request line. Unknown fields are ignored — older clients
/// keep working against newer servers.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = json::parse(line)
        .map_err(|e| Error::Config(format!("bad request line: {e}")))?;
    let cmd = v
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Config("request is missing `cmd`".into()))?
        .to_string();
    let mut options = Vec::new();
    if let Some(obj) = v.get("options").and_then(Json::as_obj) {
        for (k, val) in obj {
            let s = match val {
                Json::Str(s) => s.clone(),
                other => other.to_string(),
            };
            options.push((k.clone(), s));
        }
    }
    let mut flags = Vec::new();
    if let Some(arr) = v.get("flags").and_then(Json::as_arr) {
        for f in arr {
            if let Some(s) = f.as_str() {
                flags.push(s.to_string());
            }
        }
    }
    Ok(Request {
        cmd,
        id: v.get("id").and_then(Json::as_f64).map(|f| f as u64),
        tenant: v
            .get("tenant")
            .and_then(Json::as_str)
            .unwrap_or("default")
            .to_string(),
        weight: v
            .get("weight")
            .and_then(Json::as_f64)
            .map(|f| f as u64)
            .unwrap_or(1)
            .max(1),
        run: v.get("run").and_then(Json::as_str).map(str::to_string),
        options,
        flags,
        dedup_key: v
            .get("dedup_key")
            .and_then(Json::as_str)
            .map(str::to_string),
        after_seq: v.get("after_seq").and_then(Json::as_f64).map(|f| f as u64),
    })
}

/// Build a JSON object from `(key, value)` pairs.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

/// An `{"ok":true,...}` response line (no trailing newline).
pub fn ok(mut fields: Vec<(&str, Json)>) -> String {
    fields.insert(0, ("ok", Json::Bool(true)));
    obj(fields).to_string()
}

/// An `{"ok":false,"error":...}` response line (no trailing newline).
pub fn err(msg: &str) -> String {
    obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_round_trips() {
        let line = "{\"cmd\":\"submit\",\"run\":\"explore\",\"tenant\":\"alice\",\
                    \"weight\":2,\"options\":{\"n\":\"200\",\"chunk\":8},\
                    \"flags\":[\"degraded-ok\"]}";
        let r = parse_request(line).unwrap();
        assert_eq!(r.cmd, "submit");
        assert_eq!(r.run.as_deref(), Some("explore"));
        assert_eq!(r.tenant, "alice");
        assert_eq!(r.weight, 2);
        assert_eq!(
            r.options,
            vec![
                ("chunk".to_string(), "8".to_string()),
                ("n".to_string(), "200".to_string())
            ],
            "numeric option values are stringified"
        );
        assert_eq!(r.flags, vec!["degraded-ok".to_string()]);
        assert!(r.dedup_key.is_none());
    }

    #[test]
    fn dedup_key_and_after_seq_parse() {
        let r = parse_request(
            "{\"cmd\":\"submit\",\"run\":\"explore\",\"dedup_key\":\"job-7\"}",
        )
        .unwrap();
        assert_eq!(r.dedup_key.as_deref(), Some("job-7"));
        assert!(r.after_seq.is_none());
        let r = parse_request("{\"cmd\":\"watch\",\"id\":3,\"after_seq\":41}").unwrap();
        assert_eq!(r.after_seq, Some(41));
    }

    #[test]
    fn defaults_and_errors() {
        let r = parse_request("{\"cmd\":\"list\"}").unwrap();
        assert_eq!(r.tenant, "default");
        assert_eq!(r.weight, 1);
        assert!(r.id.is_none());
        assert!(parse_request("{\"id\":3}").is_err(), "cmd is mandatory");
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn responses_are_single_json_lines() {
        let line = ok(vec![("id", Json::Num(3.0))]);
        assert_eq!(line, "{\"id\":3,\"ok\":true}");
        assert!(!line.contains('\n'));
        let line = err("server saturated: 4 queued");
        assert_eq!(
            line,
            "{\"error\":\"server saturated: 4 queued\",\"ok\":false}"
        );
    }
}
