//! The TCP front: bind, accept, and speak the JSONL protocol — one
//! thread per connection, one JSON object per line in both directions.
//!
//! Hardened against hostile clients: per-connection read/write timeouts
//! (`--conn-timeout`), a request-line byte cap, a connection-count
//! limit that sheds load with `{"ok":false,"error":"server busy"}`
//! (`--max-conns`), and malformed lines answered with an error line
//! instead of a killed thread — one slow, garbage-spewing or
//! half-closed connection never stops well-behaved tenants.
//!
//! `watch` is the only streaming command: the connection subscribes to
//! the experiment's registry events *before* snapshotting its state (so
//! no transition can fall between snapshot and subscription), then
//! forwards seq-numbered `state`/`progress` lines until a terminal
//! state arrives. With `after_seq`, the bounded event log's missed tail
//! is replayed first — a reconnecting client resumes gap-free.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::broker::journal;
use crate::error::Result;
use crate::serve::protocol::{self, err, obj};
use crate::serve::registry::{ExpRecord, ExpState};
use crate::serve::scheduler::{ServeConfig, Server};
use crate::util::json::Json;

/// Longest request line a client may send (bytes, newline included).
const MAX_LINE: usize = 64 * 1024;

/// Run the daemon: build the [`Server`], start its scheduler, bind the
/// listen address (writing the bound address to `<state-dir>/addr` so
/// `--addr 127.0.0.1:0` is discoverable), and accept forever.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let server = Server::new(cfg)?;
    server.start();
    let listener = TcpListener::bind(&server.config().addr)?;
    let actual = listener.local_addr()?;
    let dir = server.registry().dir().to_path_buf();
    // temp + rename + dir fsync: a concurrently-starting client reads
    // either nothing or the complete address, never a partial line
    journal::atomic_write(dir.join("addr"), format!("{actual}\n").as_bytes())?;
    println!(
        "molers serve: listening on {actual} (state dir {})",
        dir.display()
    );
    let _ = std::io::stdout().flush();
    let conns = Arc::new(AtomicUsize::new(0));
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let max = server.config().max_conns;
        if max > 0 && conns.load(Ordering::SeqCst) >= max {
            shed(stream);
            continue;
        }
        conns.fetch_add(1, Ordering::SeqCst);
        let guard = ConnGuard(Arc::clone(&conns));
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            let _guard = guard;
            let _ = handle_conn(&server, stream);
        });
    }
    Ok(())
}

/// Decrements the live-connection count however the handler exits.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Refuse a connection past `--max-conns` with one error line. The
/// short write timeout keeps a full-socket-buffer attacker from
/// stalling the accept loop.
fn shed(mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let _ = writeln!(stream, "{}", err("server busy"));
}

/// One connection: read request lines until EOF, answer each.
fn handle_conn(server: &Arc<Server>, stream: TcpStream) -> std::io::Result<()> {
    let t = server.config().conn_timeout_s;
    let timeout = if t > 0.0 {
        Some(Duration::from_secs_f64(t))
    } else {
        None
    };
    stream.set_read_timeout(timeout)?;
    stream.set_write_timeout(timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    loop {
        let mut buf = Vec::new();
        // cap the read: a newline-less flood fills at most MAX_LINE + 1
        // bytes of memory, then gets an error instead of a thread
        let n = match (&mut reader)
            .take((MAX_LINE + 1) as u64)
            .read_until(b'\n', &mut buf)
        {
            Ok(n) => n,
            // a stalled client tripped the read timeout: close quietly
            Err(_) => return Ok(()),
        };
        if n == 0 {
            return Ok(());
        }
        let complete = buf.last() == Some(&b'\n');
        if !complete && buf.len() > MAX_LINE {
            writeln!(
                out,
                "{}",
                err(&format!("request line exceeds {MAX_LINE} bytes"))
            )?;
            return Ok(());
        }
        let Ok(line) = String::from_utf8(buf) else {
            writeln!(out, "{}", err("request line is not valid UTF-8"))?;
            out.flush()?;
            if complete {
                continue;
            }
            return Ok(());
        };
        let line = line.trim();
        if line.is_empty() {
            if complete {
                continue;
            }
            return Ok(());
        }
        let req = match protocol::parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                writeln!(out, "{}", err(&e.to_string()))?;
                out.flush()?;
                if complete {
                    continue;
                }
                return Ok(());
            }
        };
        match req.cmd.as_str() {
            "shutdown" => {
                writeln!(out, "{}", protocol::ok(vec![("shutdown", Json::Bool(true))]))?;
                out.flush()?;
                // journals are synced per the durability policy; exiting
                // here is the crash the restart path survives anyway
                std::process::exit(0);
            }
            "watch" => {
                let Some(id) = req.id else {
                    writeln!(out, "{}", err("`watch` requires `id`"))?;
                    continue;
                };
                watch(server, &mut out, id, req.after_seq)?;
            }
            _ => {
                writeln!(out, "{}", server.handle(&req))?;
            }
        }
        out.flush()?;
        if !complete {
            // the line arrived without a newline right before EOF —
            // answered, nothing more can follow
            return Ok(());
        }
    }
}

/// Stream an experiment's events until it reaches a terminal state.
fn watch(
    server: &Arc<Server>,
    out: &mut TcpStream,
    id: u64,
    after_seq: Option<u64>,
) -> std::io::Result<()> {
    // subscribe FIRST: any transition after the snapshot/replay below
    // arrives on the live channel, so no event can slip between the two
    let sub = server.registry().subscribe(id, after_seq);
    let Some(rec) = server.registry().get(id) else {
        writeln!(out, "{}", err(&format!("unknown experiment id {id}")))?;
        return Ok(());
    };
    if after_seq.is_none() || sub.gap {
        // fresh watch — or the bounded log evicted the requested tail:
        // synthesize a snapshot carrying the newest assigned seq, which
        // is a valid resume point for the next reconnect
        writeln!(out, "{}", state_event(&rec, sub.last_seq))?;
        out.flush()?;
        if rec.state.is_terminal() {
            return Ok(());
        }
    } else {
        for ev in &sub.replay {
            let terminal = is_terminal_state_event(ev);
            writeln!(out, "{ev}")?;
            if terminal {
                out.flush()?;
                return Ok(());
            }
        }
        out.flush()?;
        if rec.state.is_terminal() {
            // the terminal transition predates `after_seq` (the client
            // already saw it) — restate it so this watch still ends
            writeln!(out, "{}", state_event(&rec, sub.last_seq))?;
            out.flush()?;
            return Ok(());
        }
    }
    loop {
        match sub.rx.recv_timeout(Duration::from_millis(300)) {
            Ok(ev) => {
                let terminal = is_terminal_state_event(&ev);
                writeln!(out, "{ev}")?;
                out.flush()?;
                if terminal {
                    return Ok(());
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // belt-and-braces: if the sender side was somehow torn
                // down between events, fall back to polling the registry
                if let Some(rec) = server.registry().get(id) {
                    if rec.state.is_terminal() {
                        let seq = server.registry().last_seq();
                        writeln!(out, "{}", state_event(&rec, seq))?;
                        out.flush()?;
                        return Ok(());
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
        }
    }
}

/// Is this a `state` event naming a terminal state?
fn is_terminal_state_event(ev: &Json) -> bool {
    ev.get("event").and_then(Json::as_str) == Some("state")
        && ev
            .get("state")
            .and_then(Json::as_str)
            .and_then(ExpState::parse)
            .is_some_and(|s| s.is_terminal())
}

/// An experiment's current state as one `{"event":"state",...}` line,
/// stamped with an explicit seq (snapshots are synthesized, not drawn
/// from the event log, so they carry the caller's resume point).
fn state_event(rec: &ExpRecord, seq: u64) -> String {
    let mut fields = vec![
        ("event", Json::Str("state".into())),
        ("id", Json::Num(rec.id as f64)),
        ("state", Json::Str(rec.state.as_str().into())),
        ("seq", Json::Num(seq as f64)),
    ];
    if let Some(e) = &rec.error {
        fields.push(("error", Json::Str(e.clone())));
    }
    obj(fields).to_string()
}
