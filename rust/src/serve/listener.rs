//! The TCP front: bind, accept, and speak the JSONL protocol — one
//! thread per connection, one JSON object per line in both directions.
//!
//! `watch` is the only streaming command: the connection subscribes to
//! the experiment's registry events *before* snapshotting its state (so
//! no transition can fall between snapshot and subscription), then
//! forwards `state`/`progress` lines until a terminal state arrives.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::error::Result;
use crate::serve::protocol::{self, err, obj, ok};
use crate::serve::registry::ExpRecord;
use crate::serve::scheduler::{ServeConfig, Server};
use crate::util::json::Json;

/// Run the daemon: build the [`Server`], start its scheduler, bind the
/// listen address (writing the bound address to `<state-dir>/addr` so
/// `--addr 127.0.0.1:0` is discoverable), and accept forever.
pub fn serve(cfg: ServeConfig) -> Result<()> {
    let server = Server::new(cfg)?;
    server.start();
    let listener = TcpListener::bind(&server.config().addr)?;
    let actual = listener.local_addr()?;
    let dir = server.registry().dir().to_path_buf();
    std::fs::write(dir.join("addr"), format!("{actual}\n"))?;
    println!(
        "molers serve: listening on {actual} (state dir {})",
        dir.display()
    );
    let _ = std::io::stdout().flush();
    for stream in listener.incoming() {
        let Ok(stream) = stream else { continue };
        let server = Arc::clone(&server);
        std::thread::spawn(move || {
            let _ = handle_conn(&server, stream);
        });
    }
    Ok(())
}

/// One connection: read request lines until EOF, answer each.
fn handle_conn(server: &Arc<Server>, stream: TcpStream) -> std::io::Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match protocol::parse_request(&line) {
            Ok(r) => r,
            Err(e) => {
                writeln!(out, "{}", err(&e.to_string()))?;
                continue;
            }
        };
        match req.cmd.as_str() {
            "shutdown" => {
                writeln!(out, "{}", ok(vec![("shutdown", Json::Bool(true))]))?;
                out.flush()?;
                // journals are flushed per record; exiting here is the
                // crash the restart path is built to survive anyway
                std::process::exit(0);
            }
            "watch" => {
                let Some(id) = req.id else {
                    writeln!(out, "{}", err("`watch` requires `id`"))?;
                    continue;
                };
                watch(server, &mut out, id)?;
            }
            _ => {
                writeln!(out, "{}", server.handle(&req))?;
            }
        }
        out.flush()?;
    }
    Ok(())
}

/// Stream an experiment's events until it reaches a terminal state.
fn watch(server: &Arc<Server>, out: &mut TcpStream, id: u64) -> std::io::Result<()> {
    // subscribe FIRST: any transition after this snapshot arrives as an
    // event, so the terminal state can never slip between the two
    let rx = server.registry().subscribe(id);
    let Some(rec) = server.registry().get(id) else {
        writeln!(out, "{}", err(&format!("unknown experiment id {id}")))?;
        return Ok(());
    };
    writeln!(out, "{}", state_event(&rec))?;
    out.flush()?;
    if rec.state.is_terminal() {
        return Ok(());
    }
    loop {
        match rx.recv_timeout(Duration::from_millis(300)) {
            Ok(ev) => {
                let terminal = ev.get("event").and_then(Json::as_str) == Some("state")
                    && ev
                        .get("state")
                        .and_then(Json::as_str)
                        .and_then(crate::serve::registry::ExpState::parse)
                        .is_some_and(|s| s.is_terminal());
                writeln!(out, "{ev}")?;
                out.flush()?;
                if terminal {
                    return Ok(());
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // belt-and-braces: if the sender side was somehow torn
                // down between events, fall back to polling the registry
                if let Some(rec) = server.registry().get(id) {
                    if rec.state.is_terminal() {
                        writeln!(out, "{}", state_event(&rec))?;
                        out.flush()?;
                        return Ok(());
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return Ok(()),
        }
    }
}

/// An experiment's current state as one `{"event":"state",...}` line.
fn state_event(rec: &ExpRecord) -> String {
    let mut fields = vec![
        ("event", Json::Str("state".into())),
        ("id", Json::Num(rec.id as f64)),
        ("state", Json::Str(rec.state.as_str().into())),
    ];
    if let Some(e) = &rec.error {
        fields.push(("error", Json::Str(e.clone())));
    }
    obj(fields).to_string()
}
