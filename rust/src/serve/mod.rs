//! `molers serve` — a multi-tenant experiment service over one shared
//! broker (ROADMAP item 1, the "production traffic" jump): a persistent
//! daemon that accepts [`Experiment`](crate::workflow::Experiment)
//! submissions from many concurrent clients, runs them over **one**
//! fleet + thread pool, streams progress back, and survives its own
//! death by replaying journals.
//!
//! ## Wire protocol
//!
//! One JSON object per line over TCP — the same dependency-free line
//! format as the [journal](crate::broker::journal). Requests:
//!
//! ```text
//! {"cmd":"submit","run":"explore","tenant":"alice","weight":2,
//!  "options":{"n":"200","chunk":"8","sampling":"sobol"},
//!  "flags":["degraded-ok"],"dedup_key":"sweep-2026-08"}
//! {"cmd":"list"}
//! {"cmd":"status","id":3}
//! {"cmd":"watch","id":3,"after_seq":41}
//! {"cmd":"cancel","id":3}
//! {"cmd":"result","id":3}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Every response is one `{"ok":true,...}` / `{"ok":false,"error":...}`
//! line; `watch` instead streams `{"event":"state"|"progress",...}` lines
//! until the experiment reaches a terminal state. Submission options are
//! the method's own CLI options, verbatim — the server builds the same
//! [`Experiment`](crate::workflow::Experiment) that `molers <run> ...`
//! would, via [`front::by_name`](crate::cli::front::by_name). Fleet and
//! persistence options (`--envs`, `--out`, `--journal`, ...) are
//! server-owned and stripped from submissions.
//!
//! ### Idempotent submission
//!
//! `submit` takes an optional `dedup_key` (per tenant). The registry
//! journals the key with the experiment, so retrying a submit whose
//! response was lost — or retrying against a *restarted* daemon —
//! returns the original experiment id with `"deduped":true` and the
//! experiment's current state, instead of double-running the work. The
//! check-and-insert is atomic: two racing retries can never both
//! register.
//!
//! ### Resumable watch streams
//!
//! Every `watch` event carries a monotone `seq` (global across
//! experiments). A reconnecting watcher sends `after_seq` with the last
//! seq it saw; the server replays the missed transitions from a bounded
//! in-memory event log before streaming live ones — gap-free across
//! connection drops. When the requested tail has been evicted from the
//! log, the server falls back to a fresh state snapshot (stamped with
//! the newest seq, which is again a valid resume point).
//! `molers client watch` does the reconnect dance automatically, with
//! backoff.
//!
//! ### Hostile clients
//!
//! The listener enforces per-connection read/write timeouts
//! (`--conn-timeout`, default 30 s), caps request lines at 64 KiB,
//! sheds connections past `--max-conns` (default 256) with
//! `{"ok":false,"error":"server busy"}`, and answers garbage bytes or
//! malformed JSON with an error line — a slow-loris, a binary-spewing
//! or a half-closed connection never pins a thread or stops
//! well-behaved tenants.
//!
//! ## Admission control and fair scheduling
//!
//! Submissions are validated (a bad method/option is rejected with the
//! CLI's own error before an id is allocated), then admitted into a
//! bounded queue — a saturated server answers
//! `{"ok":false,"error":"server saturated: ..."}` instead of queueing
//! unboundedly. At most `max_running` experiments execute concurrently,
//! and their jobs meet at a [`FairShare`](crate::broker::FairShare) gate
//! in front of the shared broker: weighted round-robin across tenants'
//! pending chunks, so a 200k-row sweep cannot starve a 100-row run (see
//! [`crate::broker::fairshare`] for the discipline).
//!
//! ## Restart survival and durability
//!
//! The state directory is the source of truth:
//!
//! ```text
//! <dir>/server.jsonl        meta-journal segment 0 (submissions +
//!                           terminal states; replayed on start)
//! <dir>/server.N.jsonl      later meta-journal segments (N ≥ 1)
//! <dir>/addr                the bound listen address, written
//!                           atomically (temp + rename + dir fsync)
//! <dir>/exp-N.jsonl         per-experiment checkpoint journal
//! <dir>/exp-N.csv           explore result file (fsync'd before the
//!                           terminal state that advertises it)
//! <dir>/exp-N.result.jsonl  terminal summary + pareto points (written
//!                           atomically)
//! <dir>/exp-N.front.jsonl   durable pareto front for evolution methods
//!                           (the deterministic format `molers reexec`
//!                           digests — no wall times)
//! <dir>/exp-N.manifest.json provenance manifest (see
//!                           [`crate::provenance`]), written atomically
//!                           before the terminal state that advertises
//!                           it; `status`/`result` responses carry its
//!                           path as `"manifest"` once present, and
//!                           `molers reexec <path>` reproduces the run
//! ```
//!
//! Journal appends obey the server's [`Durability`](crate::broker::Durability)
//! policy (`--durability`, default `always`): the daemon acknowledges a
//! submission or terminal state only after `fdatasync`, so an
//! acknowledged record survives power loss — `batch:N` bounds the loss
//! window instead, `os` restores the flush-only behaviour. Replay folds
//! every segment in order; when more than one exists at startup the
//! folded table is compacted into a single snapshot segment
//! (`server.(max+1).jsonl`, atomic write, then the old segments are
//! deleted), and a long run rolls the same way — replay cost stays
//! O(live experiments), not O(history), and a crash between any two
//! steps replays idempotently.
//!
//! On restart every non-terminal experiment is re-enqueued: methods with
//! a usable checkpoint resume from their own journal (the PR 2/4/6
//! machinery — an explore resumes to a byte-identical result file),
//! methods whose journal holds no checkpoint restart from scratch under
//! the same seed, and failures during restoration mark the experiment
//! `degraded` rather than losing it silently. Experiments are keyed by a
//! monotone id, so two experiments never collide on journal or result
//! file names.

pub mod client;
pub mod listener;
pub mod protocol;
pub mod registry;
pub mod scheduler;

pub use listener::serve;
pub use protocol::{Request, DEFAULT_ADDR};
pub use registry::{ExpRecord, ExpState, Registry, WatchSub};
pub use scheduler::{ServeConfig, Server};
