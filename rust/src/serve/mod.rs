//! `molers serve` — a multi-tenant experiment service over one shared
//! broker (ROADMAP item 1, the "production traffic" jump): a persistent
//! daemon that accepts [`Experiment`](crate::workflow::Experiment)
//! submissions from many concurrent clients, runs them over **one**
//! fleet + thread pool, streams progress back, and survives its own
//! death by replaying journals.
//!
//! ## Wire protocol
//!
//! One JSON object per line over TCP — the same dependency-free line
//! format as the [journal](crate::broker::journal). Requests:
//!
//! ```text
//! {"cmd":"submit","run":"explore","tenant":"alice","weight":2,
//!  "options":{"n":"200","chunk":"8","sampling":"sobol"},
//!  "flags":["degraded-ok"]}
//! {"cmd":"list"}
//! {"cmd":"status","id":3}
//! {"cmd":"watch","id":3}
//! {"cmd":"cancel","id":3}
//! {"cmd":"result","id":3}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//!
//! Every response is one `{"ok":true,...}` / `{"ok":false,"error":...}`
//! line; `watch` instead streams `{"event":"state"|"progress",...}` lines
//! until the experiment reaches a terminal state. Submission options are
//! the method's own CLI options, verbatim — the server builds the same
//! [`Experiment`](crate::workflow::Experiment) that `molers <run> ...`
//! would, via [`front::by_name`](crate::cli::front::by_name). Fleet and
//! persistence options (`--envs`, `--out`, `--journal`, ...) are
//! server-owned and stripped from submissions.
//!
//! ## Admission control and fair scheduling
//!
//! Submissions are validated (a bad method/option is rejected with the
//! CLI's own error before an id is allocated), then admitted into a
//! bounded queue — a saturated server answers
//! `{"ok":false,"error":"server saturated: ..."}` instead of queueing
//! unboundedly. At most `max_running` experiments execute concurrently,
//! and their jobs meet at a [`FairShare`](crate::broker::FairShare) gate
//! in front of the shared broker: weighted round-robin across tenants'
//! pending chunks, so a 200k-row sweep cannot starve a 100-row run (see
//! [`crate::broker::fairshare`] for the discipline).
//!
//! ## Restart survival
//!
//! The state directory is the source of truth:
//!
//! ```text
//! <dir>/server.jsonl        submissions + terminal states (replayed)
//! <dir>/addr                the bound listen address (for tests/scripts)
//! <dir>/exp-N.jsonl         per-experiment checkpoint journal
//! <dir>/exp-N.csv           explore result file
//! <dir>/exp-N.result.jsonl  terminal summary + pareto points
//! ```
//!
//! On restart every non-terminal experiment is re-enqueued: methods with
//! a usable checkpoint resume from their own journal (the PR 2/4/6
//! machinery — an explore resumes to a byte-identical result file),
//! methods whose journal holds no checkpoint restart from scratch under
//! the same seed, and failures during restoration mark the experiment
//! `degraded` rather than losing it silently. Experiments are keyed by a
//! monotone id, so two experiments never collide on journal or result
//! file names.

pub mod client;
pub mod listener;
pub mod protocol;
pub mod registry;
pub mod scheduler;

pub use listener::serve;
pub use protocol::{Request, DEFAULT_ADDR};
pub use registry::{ExpRecord, ExpState, Registry};
pub use scheduler::{ServeConfig, Server};
