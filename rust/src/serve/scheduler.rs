//! The server core: one shared fleet, admission control, the experiment
//! scheduler, and request handling.
//!
//! All submissions execute over **one** broker + thread pool behind a
//! [`FairShare`] gate — each experiment runs on its tenant's
//! [`TenantEnv`](crate::broker::TenantEnv), so concurrent campaigns share
//! the fleet by weighted round-robin instead of FIFO job order. At most
//! `max_running` experiments execute concurrently (each gets a runner
//! thread; the fair gate interleaves their chunks), and at most
//! `max_queued` wait behind them — past that, submissions are rejected
//! with a reason instead of queueing unboundedly.
//!
//! Lock order: `sched` before the registry's interior locks. The fair
//! gate has its own ordering (see [`crate::broker::fairshare`]) and is
//! never called with `sched` held.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::broker::{journal, policy, Broker, Durability, FairShare, Journal, RetryPolicy};
use crate::cli::{front, Args};
use crate::environment::{EnvStats, Environment};
use crate::error::{Error, Result};
use crate::provenance::{self, EnvDesc, RunManifest};
use crate::serve::protocol::{self, err, obj, ok, Request, DEFAULT_ADDR};
use crate::serve::registry::{ExpRecord, ExpState, Registry};
use crate::util::json::Json;

/// Options/flags a submission may NOT carry: the server owns the fleet,
/// persistence and addressing. Rejecting silently would let a client
/// believe e.g. `--envs` took effect, so these are stripped *and* the
/// strip is part of the documented protocol (see [`crate::serve`]).
const SERVER_OWNED: &[&str] = &[
    "out",
    "journal",
    "resume",
    "env",
    "envs",
    "policy",
    "addr",
    "tenant",
    "weight",
    "id",
    "state-dir",
    "max-running",
    "max-queued",
    "slots",
    "speculate",
    "timeout",
    "max-retries",
    "backoff",
    "durability",
    "max-conns",
    "conn-timeout",
    "dedup-key",
    "after-seq",
    "retries",
    // the server owns disk layout: a budgeted explore spills under the
    // state dir (`--mem-budget` itself stays client-suppliable)
    "spill-dir",
];

/// `molers serve` configuration (parsed from CLI flags).
#[derive(Clone)]
pub struct ServeConfig {
    pub addr: String,
    pub state_dir: String,
    /// Fleet spec shared by every experiment (`--envs local:8,pbs:32`).
    pub envs: String,
    pub policy: String,
    /// Fair-share gate width; `0` = the fleet's total capacity.
    pub slots: usize,
    /// Experiments executing concurrently.
    pub max_running: usize,
    /// Experiments waiting behind them before submissions are rejected.
    pub max_queued: usize,
    pub seed: u64,
    pub retry: Option<RetryPolicy>,
    /// How eagerly journals reach stable storage before the daemon
    /// acknowledges (`--durability always|batch[:N]|os`, default
    /// `always` — an acknowledged record survives power loss).
    pub durability: Durability,
    /// Concurrent connections before the listener sheds load with
    /// `server busy` (`--max-conns`, `0` = unlimited).
    pub max_conns: usize,
    /// Per-connection read/write timeout in seconds (`--conn-timeout`,
    /// `0` = none). Watch streams are exempt from the read side.
    pub conn_timeout_s: f64,
}

impl ServeConfig {
    pub fn from_args(args: &Args) -> Result<Self> {
        let n = |r: std::result::Result<usize, String>| r.map_err(Error::Config);
        let d = args.get_or("durability", "always").to_string();
        let durability = Durability::parse(&d).ok_or_else(|| {
            Error::Config(format!("invalid --durability `{d}` (always|batch[:N]|os)"))
        })?;
        Ok(ServeConfig {
            addr: args.get_or("addr", DEFAULT_ADDR).to_string(),
            state_dir: args.get_or("state-dir", "molers-serve").to_string(),
            envs: args.get_or("envs", "local:8").to_string(),
            policy: args.get_or("policy", "ewma").to_string(),
            slots: n(args.usize("slots", 0))?,
            max_running: n(args.usize("max-running", 4))?.max(1),
            max_queued: n(args.usize("max-queued", 64))?,
            seed: args.u64("seed", 42).map_err(Error::Config)?,
            retry: front::retry_overrides(args)?,
            durability,
            max_conns: n(args.usize("max-conns", 256))?,
            conn_timeout_s: args.f64("conn-timeout", 30.0).map_err(Error::Config)?,
        })
    }
}

struct Sched {
    queue: VecDeque<u64>,
    running: usize,
}

/// The daemon: shared fleet + fair gate + registry + scheduler.
pub struct Server {
    registry: Arc<Registry>,
    broker: Arc<Broker>,
    fair: Arc<FairShare>,
    cfg: ServeConfig,
    sched: Mutex<Sched>,
    wake: Condvar,
    cancels: Mutex<HashMap<u64, Arc<AtomicBool>>>,
}

impl Server {
    /// Build the shared fleet, open (replaying) the state directory, and
    /// re-enqueue every experiment that was unfinished at the last
    /// shutdown.
    pub fn new(cfg: ServeConfig) -> Result<Arc<Server>> {
        let pool = Arc::new(crate::exec::ThreadPool::default_size());
        let p = policy::by_name(&cfg.policy).ok_or_else(|| {
            Error::Config(format!(
                "unknown --policy `{}` (roundrobin|least|ewma)",
                cfg.policy
            ))
        })?;
        let mut builder = Broker::spec_builder(&cfg.envs, pool, cfg.seed)?.policy(p);
        if let Some(r) = &cfg.retry {
            builder = builder.retry(r.clone());
        }
        let broker = Arc::new(builder.build()?);
        let slots = if cfg.slots > 0 {
            cfg.slots
        } else {
            broker
                .backend_snapshots()
                .iter()
                .map(|b| b.capacity)
                .sum::<usize>()
                .max(1)
        };
        let fair = FairShare::new(Arc::clone(&broker) as Arc<dyn Environment>, slots);
        let registry = Arc::new(Registry::open_with(&cfg.state_dir, cfg.durability)?);
        let queue: VecDeque<u64> = registry.queued_ids().into_iter().collect();
        Ok(Arc::new(Server {
            registry,
            broker,
            fair,
            cfg,
            sched: Mutex::new(Sched { queue, running: 0 }),
            wake: Condvar::new(),
            cancels: Mutex::new(HashMap::new()),
        }))
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Start the scheduler thread: pops queued experiments and runs up to
    /// `max_running` of them on runner threads. Daemon-style — lives for
    /// the whole process.
    pub fn start(self: &Arc<Self>) {
        let server = Arc::clone(self);
        std::thread::spawn(move || loop {
            let id = {
                let mut sched = server.sched.lock().unwrap();
                loop {
                    if sched.running < server.cfg.max_running {
                        if let Some(id) = sched.queue.pop_front() {
                            sched.running += 1;
                            break id;
                        }
                    }
                    sched = server.wake.wait(sched).unwrap();
                }
            };
            let runner = Arc::clone(&server);
            std::thread::spawn(move || {
                runner.run_one(id);
                let mut sched = runner.sched.lock().unwrap();
                sched.running -= 1;
                drop(sched);
                runner.wake.notify_all();
            });
        });
    }

    // -- request handling ---------------------------------------------

    /// Handle every single-response command (`watch` streams and is
    /// driven by the listener via [`Server::registry`]).
    pub fn handle(&self, req: &Request) -> String {
        match req.cmd.as_str() {
            "submit" => self.submit(req),
            "list" => self.list(),
            "status" => self.with_id(req, |s, id| s.status(id)),
            "cancel" => self.with_id(req, |s, id| s.cancel(id)),
            "result" => self.with_id(req, |s, id| s.result(id)),
            "ping" => ok(vec![("pong", Json::Bool(true))]),
            other => err(&format!(
                "unknown cmd `{other}` \
                 (submit|list|status|watch|cancel|result|ping|shutdown)"
            )),
        }
    }

    fn with_id(&self, req: &Request, f: impl Fn(&Self, u64) -> String) -> String {
        match req.id {
            Some(id) => f(self, id),
            None => err(&format!("`{}` requires `id`", req.cmd)),
        }
    }

    /// Validate, admit, journal, enqueue — in that order, so a rejected
    /// submission allocates no id and leaves no trace. A known
    /// `dedup_key` short-circuits everything (including the saturation
    /// check — a retried submission's work is already admitted).
    fn submit(&self, req: &Request) -> String {
        let Some(run) = &req.run else {
            return err("submit requires `run` (run|explore|replicate|calibrate|island)");
        };
        if let Some(k) = &req.dedup_key {
            if let Some(id) = self.registry.dedup_lookup(&req.tenant, k) {
                return self.dedup_response(id);
            }
        }
        let argv = sanitize_argv(run, &req.options, &req.flags);
        // build the experiment once now purely for validation: a bad
        // method or option gets the CLI front's own error message back
        let parsed = match Args::parse(argv.iter().cloned()) {
            Ok(a) => a,
            Err(e) => return err(&e),
        };
        if let Err(e) = front::by_name(run, &parsed) {
            return err(&e.to_string());
        }

        let mut sched = self.sched.lock().unwrap();
        if sched.queue.len() >= self.cfg.max_queued {
            return err(&format!(
                "server saturated: {} experiments queued (max {}) — retry later",
                sched.queue.len(),
                self.cfg.max_queued
            ));
        }
        let (id, fresh) = match self.registry.submit(
            &req.tenant,
            req.weight,
            run,
            argv,
            req.dedup_key.as_deref(),
        ) {
            Ok(v) => v,
            Err(e) => return err(&e.to_string()),
        };
        if !fresh {
            // a racing retry lost the check-and-insert — same answer as
            // the fast path above
            drop(sched);
            return self.dedup_response(id);
        }
        sched.queue.push_back(id);
        drop(sched);
        self.cancels
            .lock()
            .unwrap()
            .insert(id, Arc::new(AtomicBool::new(false)));
        self.wake.notify_all();
        ok(vec![
            ("id", Json::Num(id as f64)),
            ("state", Json::Str("queued".into())),
        ])
    }

    /// The response a deduplicated submit gets: the original id, its
    /// *current* state, and an explicit `deduped` marker.
    fn dedup_response(&self, id: u64) -> String {
        let state = self
            .registry
            .get(id)
            .map(|r| r.state.as_str())
            .unwrap_or("queued");
        ok(vec![
            ("id", Json::Num(id as f64)),
            ("state", Json::Str(state.into())),
            ("deduped", Json::Bool(true)),
        ])
    }

    fn list(&self) -> String {
        let rows = self
            .registry
            .list()
            .into_iter()
            .map(|r| {
                obj(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("run", Json::Str(r.run)),
                    ("tenant", Json::Str(r.tenant)),
                    ("state", Json::Str(r.state.as_str().into())),
                    ("done", Json::Num(r.done as f64)),
                    ("total", Json::Num(r.total as f64)),
                ])
            })
            .collect();
        ok(vec![("experiments", Json::Arr(rows))])
    }

    fn status(&self, id: u64) -> String {
        let Some(r) = self.registry.get(id) else {
            return err(&format!("unknown experiment id {id}"));
        };
        let mut fields = vec![
            ("id", Json::Num(r.id as f64)),
            ("run", Json::Str(r.run)),
            ("tenant", Json::Str(r.tenant)),
            ("state", Json::Str(r.state.as_str().into())),
            (
                "history",
                Json::Arr(r.history.iter().map(|s| Json::Str((*s).into())).collect()),
            ),
            ("done", Json::Num(r.done as f64)),
            ("total", Json::Num(r.total as f64)),
            ("restored", Json::Bool(r.restored)),
            // fleet-wide environment stats, including the broker-enforced
            // timeout count and chaos-injected fault count
            ("fleet", env_stats_json(&self.broker.stats())),
        ];
        if let Some(e) = r.error {
            fields.push(("error", Json::Str(e)));
        }
        if let Some(s) = r.summary {
            fields.push(("summary", s));
        }
        // provenance: advertised only once the file is durably in place
        let mpath = self.registry.manifest_path(id);
        if Path::new(&mpath).exists() {
            fields.push(("manifest", Json::Str(mpath)));
        }
        ok(fields)
    }

    fn cancel(&self, id: u64) -> String {
        let Some(r) = self.registry.get(id) else {
            return err(&format!("unknown experiment id {id}"));
        };
        if r.state.is_terminal() {
            return err(&format!("experiment {id} is already {}", r.state.as_str()));
        }
        self.cancel_token(id).store(true, Ordering::SeqCst);
        // still queued → finish it here; running → the runner observes the
        // token (queued fair-share jobs fail fast) and finishes it
        let was_queued = {
            let mut sched = self.sched.lock().unwrap();
            let before = sched.queue.len();
            sched.queue.retain(|&q| q != id);
            sched.queue.len() != before
        };
        if was_queued {
            if let Err(e) = self.registry.finish(
                id,
                ExpState::Cancelled,
                Some("cancelled while queued".into()),
                None,
            ) {
                return err(&e.to_string());
            }
            return ok(vec![
                ("id", Json::Num(id as f64)),
                ("state", Json::Str("cancelled".into())),
            ]);
        }
        ok(vec![
            ("id", Json::Num(id as f64)),
            ("state", Json::Str("cancelling".into())),
        ])
    }

    fn result(&self, id: u64) -> String {
        let Some(r) = self.registry.get(id) else {
            return err(&format!("unknown experiment id {id}"));
        };
        if !matches!(r.state, ExpState::Done | ExpState::Degraded) {
            return err(&format!(
                "experiment {id} is {} — results exist once it is done or degraded",
                r.state.as_str()
            ));
        }
        let path = if r.run == "explore" {
            self.registry.csv_path(id)
        } else {
            self.registry.result_path(id)
        };
        match std::fs::read_to_string(&path) {
            Ok(content) => {
                let mut fields = vec![
                    ("id", Json::Num(id as f64)),
                    ("path", Json::Str(path)),
                    ("content", Json::Str(content)),
                ];
                let mpath = self.registry.manifest_path(id);
                if Path::new(&mpath).exists() {
                    fields.push(("manifest", Json::Str(mpath)));
                }
                ok(fields)
            }
            Err(e) => err(&format!("result file `{path}` unreadable: {e}")),
        }
    }

    // -- execution ----------------------------------------------------

    fn cancel_token(&self, id: u64) -> Arc<AtomicBool> {
        Arc::clone(
            self.cancels
                .lock()
                .unwrap()
                .entry(id)
                .or_insert_with(|| Arc::new(AtomicBool::new(false))),
        )
    }

    /// Run one experiment to a terminal state. Never panics the runner:
    /// every failure path lands in [`Registry::finish`].
    fn run_one(&self, id: u64) {
        let Some(rec) = self.registry.get(id) else {
            return;
        };
        if rec.state.is_terminal() {
            return;
        }
        let token = self.cancel_token(id);
        if token.load(Ordering::SeqCst) {
            let _ = self.registry.finish(
                id,
                ExpState::Cancelled,
                Some("cancelled while queued".into()),
                None,
            );
            return;
        }
        self.registry.set_running(id);
        match self.execute(&rec, Arc::clone(&token)) {
            Ok(report) => {
                let state = if report.outcome.degraded.is_empty() {
                    ExpState::Done
                } else {
                    ExpState::Degraded
                };
                // explore writes its own CSV — push it to stable storage
                // before the terminal state that advertises it
                if rec.run == "explore" {
                    journal::fsync_file(self.registry.csv_path(id));
                }
                if let Err(e) = self.write_result_file(&rec, &report) {
                    let _ = self.registry.finish(
                        id,
                        ExpState::Degraded,
                        Some(format!("result file write failed: {e}")),
                        Some(summary_json(&report)),
                    );
                    return;
                }
                // provenance manifest (and the durable pareto front it
                // digests) land atomically BEFORE the terminal state, so
                // a `done` status never advertises a missing manifest
                if let Err(e) = self.write_manifest(&rec, &report) {
                    let _ = self.registry.finish(
                        id,
                        ExpState::Degraded,
                        Some(format!("manifest write failed: {e}")),
                        Some(summary_json(&report)),
                    );
                    return;
                }
                let _ = self
                    .registry
                    .finish(id, state, None, Some(summary_json(&report)));
            }
            Err(e) => {
                let (state, msg) = if token.load(Ordering::SeqCst) {
                    (ExpState::Cancelled, format!("cancelled: {e}"))
                } else if rec.restored {
                    // a restored run that cannot re-execute is degraded,
                    // not silently lost
                    (ExpState::Degraded, format!("restore failed: {e}"))
                } else {
                    (ExpState::Failed, e.to_string())
                };
                let _ = self.registry.finish(id, state, Some(msg), None);
            }
        }
    }

    /// Build the experiment from the journaled argv and run it on this
    /// tenant's fair-share environment, streaming progress into the
    /// registry.
    fn execute(
        &self,
        rec: &ExpRecord,
        token: Arc<AtomicBool>,
    ) -> Result<crate::workflow::ExperimentReport> {
        let mut argv = rec.argv.clone();
        if rec.run == "explore" {
            argv.push("--out".into());
            argv.push(self.registry.csv_path(rec.id));
            // a budgeted explore pages rows out of core — under the state
            // dir, never a client-chosen path (`spill-dir` is stripped at
            // submission)
            if argv.iter().any(|a| a == "--mem-budget") {
                argv.push("--spill-dir".into());
                argv.push(self.registry.spill_dir(rec.id));
            }
        }
        if matches!(rec.run.as_str(), "explore" | "calibrate" | "island") {
            let jpath = self.registry.journal_path(rec.id);
            let resume = rec.restored && usable_checkpoint(&rec.run, &jpath);
            argv.push(if resume { "--resume" } else { "--journal" }.into());
            argv.push(jpath);
            // the per-experiment checkpoint journal inherits the
            // server's durability policy
            argv.push("--durability".into());
            argv.push(self.cfg.durability.to_string());
        }
        let args = Args::parse(argv).map_err(Error::Config)?;
        let exp = front::by_name(&rec.run, &args)?;
        let tenant_env = self
            .fair
            .tenant(&rec.tenant, rec.weight)
            .with_cancel(token);
        let registry = Arc::clone(&self.registry);
        let id = rec.id;
        exp.on(Arc::new(tenant_env))
            .on_progress(Arc::new(move |done, total| {
                registry.progress(id, done, total)
            }))
            .quiet()
            .run()
    }

    /// `exp-N.result.jsonl`: one summary line, then one line per pareto
    /// point (evolution methods). Explore results live in `exp-N.csv`,
    /// written by the sweep itself.
    fn write_result_file(
        &self,
        rec: &ExpRecord,
        report: &crate::workflow::ExperimentReport,
    ) -> Result<()> {
        if rec.run == "explore" {
            return Ok(());
        }
        let mut out = String::new();
        out.push_str(&summary_json(report).to_string());
        out.push('\n');
        for ind in &report.outcome.pareto_front {
            out.push_str(
                &obj(vec![
                    (
                        "genome",
                        Json::Arr(ind.genome.iter().map(|&g| Json::Num(g)).collect()),
                    ),
                    (
                        "objectives",
                        Json::Arr(ind.objectives.iter().map(|&o| Json::Num(o)).collect()),
                    ),
                ])
                .to_string(),
            );
            out.push('\n');
        }
        // temp + fsync + rename: a crash mid-write can never leave a
        // half result file behind a terminal state
        journal::atomic_write(self.registry.result_path(rec.id), out.as_bytes())?;
        Ok(())
    }

    /// Provenance for a finished experiment: persist the deterministic
    /// result artifact (evolution methods get `exp-N.front.jsonl`; the
    /// explore sweep already wrote `exp-N.csv`), digest it together with
    /// the journal segments, and write `exp-N.manifest.json` atomically.
    /// `run`/`replicate` have no deterministic result artifact and emit
    /// no manifest.
    fn write_manifest(
        &self,
        rec: &ExpRecord,
        report: &crate::workflow::ExperimentReport,
    ) -> Result<Option<String>> {
        let result_path = match rec.run.as_str() {
            "explore" => self.registry.csv_path(rec.id),
            "calibrate" | "island" => {
                let p = self.registry.front_path(rec.id);
                provenance::write_front_file(
                    Path::new(&p),
                    &report.outcome.pareto_front,
                )?;
                p
            }
            _ => return Ok(None),
        };
        let args = Args::parse(rec.argv.iter().cloned()).map_err(Error::Config)?;
        let seed = args.u64("seed", 42).map_err(Error::Config)?;
        // the server's shared fleet is the recorded environment — exactly
        // what a reexec must rebuild (speculation is not a serve flag)
        let env = EnvDesc::Fleet {
            spec: self.cfg.envs.clone(),
            policy: self.cfg.policy.clone(),
            speculate: false,
            retry: self.cfg.retry.clone(),
        };
        let m = RunManifest::describe(
            &rec.run,
            front::provenance_argv(&args),
            seed,
            env,
            &result_path,
            Some(self.registry.journal_path(rec.id)).as_deref(),
        )?;
        let path = self.registry.manifest_path(rec.id);
        m.write(&path)?;
        Ok(Some(path))
    }
}

/// Rebuild a CLI argv from a wire submission, dropping server-owned
/// options (the strip is part of the protocol contract).
pub(crate) fn sanitize_argv(
    run: &str,
    options: &[(String, String)],
    flags: &[String],
) -> Vec<String> {
    let mut argv = vec![run.to_string()];
    for (k, v) in options {
        if !SERVER_OWNED.contains(&k.as_str()) {
            argv.push(format!("--{k}"));
            argv.push(v.clone());
        }
    }
    for f in flags {
        if !SERVER_OWNED.contains(&f.as_str()) {
            argv.push(format!("--{f}"));
        }
    }
    argv
}

/// Does this method's journal hold a checkpoint its `--resume` path will
/// accept? An unreadable or checkpoint-less journal means the restored
/// run re-executes from scratch (same seed) rather than failing resume
/// validation forever.
fn usable_checkpoint(run: &str, jpath: &str) -> bool {
    if !Path::new(jpath).exists() {
        return false;
    }
    // segmented-aware: a rolled per-run journal replays across segments,
    // a legacy single-file journal loads unchanged
    let Ok(records) = Journal::load_segmented(jpath) else {
        return false;
    };
    match run {
        // the sweep tolerates any prefix of its own journal (including
        // an empty one)
        "explore" => true,
        "calibrate" => journal::resume_state(&records).is_some(),
        "island" => journal::island_resume(&records).is_some(),
        _ => false,
    }
}

/// [`EnvStats`] as a JSON object — the `status` surface for fleet health,
/// including timed-out attempts and chaos-injected faults.
pub(crate) fn env_stats_json(s: &EnvStats) -> Json {
    protocol::obj(vec![
        ("submitted", Json::Num(s.submitted as f64)),
        ("completed", Json::Num(s.completed as f64)),
        ("failed_attempts", Json::Num(s.failed_attempts as f64)),
        ("resubmissions", Json::Num(s.resubmissions as f64)),
        ("failed_jobs", Json::Num(s.failed_jobs as f64)),
        ("timed_out_attempts", Json::Num(s.timed_out_attempts as f64)),
        ("injected_faults", Json::Num(s.injected_faults as f64)),
        ("in_flight", Json::Num(s.in_flight() as f64)),
        ("virtual_makespan", Json::Num(s.virtual_makespan)),
        ("virtual_cpu_s", Json::Num(s.virtual_cpu_s)),
    ])
}

/// One-line terminal summary: outcome + counters + the tenant's own
/// environment ledger.
fn summary_json(report: &crate::workflow::ExperimentReport) -> Json {
    let o = &report.outcome;
    obj(vec![
        ("outcome", Json::Str(o.outcome().into())),
        ("evaluations", Json::Num(o.evaluations as f64)),
        ("rows", Json::Num(o.rows as f64)),
        ("resumed", Json::Num(o.resumed as f64)),
        ("degraded_rows", Json::Num(o.degraded.len() as f64)),
        ("peak_resident_bytes", Json::Num(o.peak_resident_bytes as f64)),
        ("generations", Json::Num(o.generations as f64)),
        ("pareto_points", Json::Num(o.pareto_front.len() as f64)),
        ("virtual_makespan", Json::Num(o.virtual_makespan)),
        ("wall_s", Json::Num(report.wall.as_secs_f64())),
        ("env", env_stats_json(&report.env_stats)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn config_defaults_and_overrides() {
        let cfg = ServeConfig::from_args(&parse("serve")).unwrap();
        assert_eq!(cfg.addr, DEFAULT_ADDR);
        assert_eq!(cfg.envs, "local:8");
        assert_eq!(cfg.max_running, 4);
        assert_eq!(cfg.max_queued, 64);
        assert!(cfg.retry.is_none());
        assert_eq!(cfg.durability, Durability::Always, "serve defaults to fsync");
        assert_eq!(cfg.max_conns, 256);
        assert_eq!(cfg.conn_timeout_s, 30.0);

        let cfg = ServeConfig::from_args(&parse(
            "serve --addr 127.0.0.1:0 --envs local:2 --max-running 1 \
             --max-queued 1 --timeout 30 --durability batch:16 \
             --max-conns 3 --conn-timeout 5",
        ))
        .unwrap();
        assert_eq!(cfg.addr, "127.0.0.1:0");
        assert_eq!(cfg.max_running, 1);
        assert_eq!(cfg.max_queued, 1);
        assert!(cfg.retry.is_some(), "retry flags reach the shared fleet");
        assert_eq!(cfg.durability, Durability::Batch(16));
        assert_eq!(cfg.max_conns, 3);
        assert_eq!(cfg.conn_timeout_s, 5.0);

        let bad = ServeConfig::from_args(&parse("serve --durability sometimes"));
        assert!(bad.unwrap_err().to_string().contains("--durability"));
    }

    #[test]
    fn sanitize_strips_server_owned_options() {
        let argv = sanitize_argv(
            "explore",
            &[
                ("n".into(), "100".into()),
                ("envs".into(), "pbs:64".into()),
                ("out".into(), "/etc/passwd".into()),
                ("journal".into(), "steal.jsonl".into()),
                ("spill-dir".into(), "/etc".into()),
                ("mem-budget".into(), "1m".into()),
            ],
            &["degraded-ok".into(), "speculate".into()],
        );
        assert_eq!(
            argv,
            vec!["explore", "--n", "100", "--mem-budget", "1m", "--degraded-ok"],
            "spill-dir is server-owned; mem-budget stays client-suppliable"
        );
    }

    #[test]
    fn submit_validates_before_admitting() {
        let dir = std::env::temp_dir().join(format!(
            "molers-sched-validate-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            state_dir: dir.to_string_lossy().into_owned(),
            envs: "local:2".into(),
            policy: "ewma".into(),
            slots: 0,
            max_running: 1,
            max_queued: 4,
            seed: 1,
            retry: None,
            durability: Durability::Os,
            max_conns: 256,
            conn_timeout_s: 30.0,
        };
        let server = Server::new(cfg).unwrap();
        // no scheduler started: submissions stay queued, nothing executes
        let bad = protocol::parse_request(
            "{\"cmd\":\"submit\",\"run\":\"warp\"}",
        )
        .unwrap();
        let resp = server.handle(&bad);
        assert!(resp.contains("unknown method `warp`"), "{resp}");
        let bad = protocol::parse_request(
            "{\"cmd\":\"submit\",\"run\":\"explore\",\"options\":{\"sampling\":\"warp\"}}",
        )
        .unwrap();
        let resp = server.handle(&bad);
        assert!(resp.contains("unknown --sampling"), "{resp}");
        assert!(
            server.registry().list().is_empty(),
            "rejected submissions allocate no id"
        );

        let good = protocol::parse_request(
            "{\"cmd\":\"submit\",\"run\":\"explore\",\"options\":{\"n\":\"8\"}}",
        )
        .unwrap();
        let resp = server.handle(&good);
        assert!(resp.contains("\"ok\":true"), "{resp}");
        assert!(resp.contains("\"id\":1"), "{resp}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn saturation_rejects_with_reason() {
        let dir = std::env::temp_dir().join(format!(
            "molers-sched-saturate-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            state_dir: dir.to_string_lossy().into_owned(),
            envs: "local:2".into(),
            policy: "ewma".into(),
            slots: 0,
            max_running: 1,
            max_queued: 1,
            seed: 1,
            retry: None,
            durability: Durability::Os,
            max_conns: 256,
            conn_timeout_s: 30.0,
        };
        let server = Server::new(cfg).unwrap();
        let sub = protocol::parse_request(
            "{\"cmd\":\"submit\",\"run\":\"explore\",\"options\":{\"n\":\"8\"}}",
        )
        .unwrap();
        // scheduler not started → the first submission occupies the queue
        assert!(server.handle(&sub).contains("\"ok\":true"));
        let resp = server.handle(&sub);
        assert!(resp.contains("server saturated"), "{resp}");
        // cancelling the queued one frees the slot
        let cancel = protocol::parse_request("{\"cmd\":\"cancel\",\"id\":1}").unwrap();
        let resp = server.handle(&cancel);
        assert!(resp.contains("\"state\":\"cancelled\""), "{resp}");
        assert!(server.handle(&sub).contains("\"ok\":true"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
