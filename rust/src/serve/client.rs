//! `molers client` — the thin client: build one request line from the
//! CLI, send it over TCP, print the response line(s). No engine code
//! runs client-side; every response is the server's own JSONL, echoed
//! verbatim (scripts pipe it straight into a JSON parser).
//!
//! Robustness surface: a connect failure maps to one clear line (exit
//! code 3 — see `main`), `ping` retries with backoff so scripts can
//! await daemon startup (`--retries`), `submit --dedup-key K` makes a
//! retried submission idempotent, and `watch` auto-reconnects with the
//! last seen `seq` as `after_seq` — a killed connection resumes the
//! event stream gap-free.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::serve::protocol::{obj, DEFAULT_ADDR};
use crate::util::json::{self, Json};

/// Options the client consumes itself (addressing + submission identity
/// + retry/resume knobs) — everything else is forwarded to the server
/// as a method option.
const CLIENT_KEYS: &[&str] = &["addr", "id", "tenant", "weight", "dedup-key", "retries", "after-seq"];

/// Connect attempts for `ping` (override with `--retries`).
const PING_RETRIES: usize = 5;
/// Consecutive failed reconnects before `watch` gives up.
const WATCH_RETRIES: usize = 5;
/// First retry backoff; doubles per attempt.
const BACKOFF_MS: u64 = 100;

/// Dispatch `molers client <action> ...`.
pub fn cmd_client(args: &Args) -> Result<()> {
    let Some(action) = args.positional().first() else {
        return Err(Error::Config(
            "client requires an action \
             (submit|list|status|watch|cancel|result|ping|shutdown)"
                .into(),
        ));
    };
    let addr = args.get_or("addr", DEFAULT_ADDR).to_string();
    match action.as_str() {
        "submit" => submit(&addr, args),
        "status" | "cancel" | "result" => {
            one_shot(&addr, &obj(vec![
                ("cmd", Json::Str(action.clone())),
                ("id", Json::Num(require_id(args)? as f64)),
            ])
            .to_string())
        }
        "list" | "shutdown" => {
            one_shot(&addr, &obj(vec![("cmd", Json::Str(action.clone()))]).to_string())
        }
        "ping" => ping(&addr, args),
        "watch" => watch(&addr, require_id(args)?, args),
        other => Err(Error::Config(format!(
            "unknown client action `{other}` \
             (submit|list|status|watch|cancel|result|ping|shutdown)"
        ))),
    }
}

fn require_id(args: &Args) -> Result<u64> {
    if args.get("id").is_none() {
        return Err(Error::Config("this action requires --id N".into()));
    }
    args.u64("id", 0).map_err(Error::Config)
}

/// Is this a connect-level failure (daemon not up yet / unreachable)
/// rather than a protocol-level one?
fn is_connect_error(e: &Error) -> bool {
    matches!(e, Error::EnvironmentError { environment, .. } if environment == "client")
}

/// `molers client submit <method> --opt v --flag`: forward the parsed
/// method options verbatim as the wire payload. `--dedup-key K` rides
/// as a dedicated wire field — retrying the same submit after a lost
/// response returns the original experiment id instead of double-running.
fn submit(addr: &str, args: &Args) -> Result<()> {
    let Some(run) = args.positional().get(1) else {
        return Err(Error::Config(
            "client submit requires a method \
             (run|explore|replicate|calibrate|island)"
                .into(),
        ));
    };
    let options: Json = Json::Obj(
        args.options()
            .filter(|(k, _)| !CLIENT_KEYS.contains(k))
            .map(|(k, v)| (k.to_string(), Json::Str(v.to_string())))
            .collect(),
    );
    let flags = Json::Arr(
        args.flag_names()
            .iter()
            .filter(|f| !CLIENT_KEYS.contains(&f.as_str()))
            .map(|f| Json::Str(f.clone()))
            .collect(),
    );
    let mut fields = vec![
        ("cmd", Json::Str("submit".into())),
        ("run", Json::Str(run.clone())),
        ("tenant", Json::Str(args.get_or("tenant", "default").to_string())),
        (
            "weight",
            Json::Num(args.u64("weight", 1).map_err(Error::Config)? as f64),
        ),
        ("options", options),
        ("flags", flags),
    ];
    if let Some(k) = args.get("dedup-key") {
        fields.push(("dedup_key", Json::Str(k.to_string())));
    }
    let line = obj(fields).to_string();
    one_shot(addr, &line)
}

/// `molers client ping [--retries N]`: retry connect failures with
/// doubling backoff so scripts can await a daemon that is still
/// starting. Protocol errors are never retried.
fn ping(addr: &str, args: &Args) -> Result<()> {
    let attempts = args
        .usize("retries", PING_RETRIES)
        .map_err(Error::Config)?
        .max(1);
    let line = obj(vec![("cmd", Json::Str("ping".into()))]).to_string();
    let mut backoff = Duration::from_millis(BACKOFF_MS);
    let mut last = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(backoff);
            backoff *= 2;
        }
        match one_shot(addr, &line) {
            Err(e) if is_connect_error(&e) && attempt + 1 < attempts => last = Some(e),
            other => return other,
        }
    }
    Err(last.unwrap_or_else(|| {
        Error::Config("ping retries exhausted".into())
    }))
}

/// Send one request line, print the one response line, surface
/// `{"ok":false}` as a CLI error.
fn one_shot(addr: &str, line: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr).map_err(|e| connect_error(addr, &e))?;
    writeln!(stream, "{line}")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    let resp = resp.trim_end();
    if resp.is_empty() {
        return Err(Error::Config(format!(
            "server at {addr} closed the connection without a response"
        )));
    }
    println!("{resp}");
    check_ok(resp)
}

/// Stream `watch` events until the experiment reaches a terminal state,
/// reconnecting on a dropped connection with `after_seq` set to the
/// last seen seq — the server replays the missed tail, so the printed
/// stream stays gap-free across daemon hiccups and network drops.
fn watch(addr: &str, id: u64, args: &Args) -> Result<()> {
    // an explicit starting point lets a restarted *client* process
    // resume someone else's interrupted stream
    let mut last_seq: Option<u64> = match args.get("after-seq") {
        Some(_) => Some(args.u64("after-seq", 0).map_err(Error::Config)?),
        None => None,
    };
    let mut failures = 0usize;
    let mut backoff = Duration::from_millis(BACKOFF_MS);
    loop {
        match watch_once(addr, id, &mut last_seq) {
            Ok(true) => return Ok(()),
            Ok(false) => {
                // mid-stream drop: reconnect and replay from last_seq
                failures = 0;
                backoff = Duration::from_millis(BACKOFF_MS);
            }
            Err(e) if is_connect_error(&e) => {
                failures += 1;
                if failures >= WATCH_RETRIES {
                    return Err(e);
                }
            }
            Err(e) => return Err(e),
        }
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(Duration::from_secs(2));
    }
}

/// One watch connection. `Ok(true)` = terminal state seen; `Ok(false)`
/// = the stream dropped mid-flight (reconnect); `Err` = connect failure
/// or an explicit `{"ok":false}` from the server (fatal).
fn watch_once(addr: &str, id: u64, last_seq: &mut Option<u64>) -> Result<bool> {
    let mut stream = TcpStream::connect(addr).map_err(|e| connect_error(addr, &e))?;
    let mut fields = vec![
        ("cmd", Json::Str("watch".into())),
        ("id", Json::Num(id as f64)),
    ];
    if let Some(seq) = *last_seq {
        fields.push(("after_seq", Json::Num(seq as f64)));
    }
    if writeln!(stream, "{}", obj(fields)).is_err() || stream.flush().is_err() {
        return Ok(false);
    }
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else {
            return Ok(false);
        };
        println!("{line}");
        check_ok(&line)?;
        if let Ok(ev) = json::parse(&line) {
            if let Some(seq) = ev.get("seq").and_then(Json::as_f64) {
                let seq = seq as u64;
                if last_seq.map(|s| seq > s).unwrap_or(true) {
                    *last_seq = Some(seq);
                }
            }
            if ev.get("event").and_then(Json::as_str) == Some("state")
                && matches!(
                    ev.get("state").and_then(Json::as_str),
                    Some("done" | "degraded" | "failed" | "cancelled")
                )
            {
                return Ok(true);
            }
        }
    }
    // EOF without a terminal state: the server went away mid-stream
    Ok(false)
}

fn check_ok(line: &str) -> Result<()> {
    if let Ok(v) = json::parse(line) {
        if v.get("ok") == Some(&Json::Bool(false)) {
            let msg = v
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("server error")
                .to_string();
            return Err(Error::Config(msg));
        }
    }
    Ok(())
}

fn connect_error(addr: &str, e: &std::io::Error) -> Error {
    Error::EnvironmentError {
        environment: "client".into(),
        message: format!("cannot connect to molers serve at {addr}: {e}"),
    }
}
