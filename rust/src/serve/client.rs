//! `molers client` — the thin client: build one request line from the
//! CLI, send it over TCP, print the response line(s). No engine code
//! runs client-side; every response is the server's own JSONL, echoed
//! verbatim (scripts pipe it straight into a JSON parser).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::cli::Args;
use crate::error::{Error, Result};
use crate::serve::protocol::{obj, DEFAULT_ADDR};
use crate::util::json::{self, Json};

/// Options the client consumes itself (addressing + submission identity)
/// — everything else is forwarded to the server as a method option.
const CLIENT_KEYS: &[&str] = &["addr", "id", "tenant", "weight"];

/// Dispatch `molers client <action> ...`.
pub fn cmd_client(args: &Args) -> Result<()> {
    let Some(action) = args.positional().first() else {
        return Err(Error::Config(
            "client requires an action \
             (submit|list|status|watch|cancel|result|ping|shutdown)"
                .into(),
        ));
    };
    let addr = args.get_or("addr", DEFAULT_ADDR).to_string();
    match action.as_str() {
        "submit" => submit(&addr, args),
        "status" | "cancel" | "result" => {
            one_shot(&addr, &obj(vec![
                ("cmd", Json::Str(action.clone())),
                ("id", Json::Num(require_id(args)? as f64)),
            ])
            .to_string())
        }
        "list" | "ping" | "shutdown" => {
            one_shot(&addr, &obj(vec![("cmd", Json::Str(action.clone()))]).to_string())
        }
        "watch" => watch(&addr, require_id(args)?),
        other => Err(Error::Config(format!(
            "unknown client action `{other}` \
             (submit|list|status|watch|cancel|result|ping|shutdown)"
        ))),
    }
}

fn require_id(args: &Args) -> Result<u64> {
    if args.get("id").is_none() {
        return Err(Error::Config("this action requires --id N".into()));
    }
    args.u64("id", 0).map_err(Error::Config)
}

/// `molers client submit <method> --opt v --flag`: forward the parsed
/// method options verbatim as the wire payload.
fn submit(addr: &str, args: &Args) -> Result<()> {
    let Some(run) = args.positional().get(1) else {
        return Err(Error::Config(
            "client submit requires a method \
             (run|explore|replicate|calibrate|island)"
                .into(),
        ));
    };
    let options: Json = Json::Obj(
        args.options()
            .filter(|(k, _)| !CLIENT_KEYS.contains(k))
            .map(|(k, v)| (k.to_string(), Json::Str(v.to_string())))
            .collect(),
    );
    let flags = Json::Arr(
        args.flag_names()
            .iter()
            .filter(|f| !CLIENT_KEYS.contains(&f.as_str()))
            .map(|f| Json::Str(f.clone()))
            .collect(),
    );
    let line = obj(vec![
        ("cmd", Json::Str("submit".into())),
        ("run", Json::Str(run.clone())),
        ("tenant", Json::Str(args.get_or("tenant", "default").to_string())),
        (
            "weight",
            Json::Num(args.u64("weight", 1).map_err(Error::Config)? as f64),
        ),
        ("options", options),
        ("flags", flags),
    ])
    .to_string();
    one_shot(addr, &line)
}

/// Send one request line, print the one response line, surface
/// `{"ok":false}` as a CLI error.
fn one_shot(addr: &str, line: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr).map_err(|e| connect_error(addr, &e))?;
    writeln!(stream, "{line}")?;
    stream.flush()?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    let resp = resp.trim_end();
    if resp.is_empty() {
        return Err(Error::Config(format!(
            "server at {addr} closed the connection without a response"
        )));
    }
    println!("{resp}");
    check_ok(resp)
}

/// Stream `watch` events until the experiment reaches a terminal state.
fn watch(addr: &str, id: u64) -> Result<()> {
    let mut stream = TcpStream::connect(addr).map_err(|e| connect_error(addr, &e))?;
    writeln!(
        stream,
        "{}",
        obj(vec![
            ("cmd", Json::Str("watch".into())),
            ("id", Json::Num(id as f64)),
        ])
    )?;
    stream.flush()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        println!("{line}");
        check_ok(&line)?;
        if let Ok(ev) = json::parse(&line) {
            if ev.get("event").and_then(Json::as_str) == Some("state")
                && matches!(
                    ev.get("state").and_then(Json::as_str),
                    Some("done" | "degraded" | "failed" | "cancelled")
                )
            {
                return Ok(());
            }
        }
    }
    Ok(())
}

fn check_ok(line: &str) -> Result<()> {
    if let Ok(v) = json::parse(line) {
        if v.get("ok") == Some(&Json::Bool(false)) {
            let msg = v
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("server error")
                .to_string();
            return Err(Error::Config(msg));
        }
    }
    Ok(())
}

fn connect_error(addr: &str, e: &std::io::Error) -> Error {
    Error::EnvironmentError {
        environment: "client".into(),
        message: format!("cannot connect to molers serve at {addr}: {e}"),
    }
}
