//! The server's experiment table: id allocation, state transitions, the
//! `server.jsonl` meta-journal that makes them replayable, and the event
//! fan-out behind `watch`.
//!
//! Two record kinds are journaled (same line format as every other
//! journal in the crate):
//!
//! ```text
//! {"kind":"exp","id":3,"tenant":"alice","weight":2,"run":"explore",
//!  "argv":["explore","--n","200"]}                       at submission
//! {"kind":"exp_state","id":3,"state":"done","summary":{...}}  terminal only
//! ```
//!
//! Intermediate states (`running`, progress) are deliberately *not*
//! journaled: on replay a non-terminal experiment simply returns to
//! `queued` and the scheduler re-runs it — resuming from its own
//! per-experiment checkpoint journal where one exists. Terminal records
//! win over re-submissions, so a finished experiment is never re-run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::broker::journal::Journal;
use crate::error::Result;
use crate::serve::protocol::obj;
use crate::util::json::Json;

/// Lifecycle of one served experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpState {
    Queued,
    Running,
    Done,
    /// Finished, but some rows carry NaN objectives (`--degraded-ok`) or
    /// the run was restored without a usable checkpoint after a restart.
    Degraded,
    Failed,
    Cancelled,
}

impl ExpState {
    pub fn as_str(self) -> &'static str {
        match self {
            ExpState::Queued => "queued",
            ExpState::Running => "running",
            ExpState::Done => "done",
            ExpState::Degraded => "degraded",
            ExpState::Failed => "failed",
            ExpState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "queued" => ExpState::Queued,
            "running" => ExpState::Running,
            "done" => ExpState::Done,
            "degraded" => ExpState::Degraded,
            "failed" => ExpState::Failed,
            "cancelled" => ExpState::Cancelled,
            _ => return None,
        })
    }

    /// No further transitions once reached.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            ExpState::Done | ExpState::Degraded | ExpState::Failed | ExpState::Cancelled
        )
    }
}

/// One experiment's full record.
#[derive(Debug, Clone)]
pub struct ExpRecord {
    pub id: u64,
    pub tenant: String,
    pub weight: u64,
    /// Method name (`run|explore|replicate|calibrate|island`).
    pub run: String,
    /// Sanitized CLI argv the server re-parses to build the experiment
    /// (journaled, so a restart rebuilds the identical configuration).
    pub argv: Vec<String>,
    pub state: ExpState,
    /// States visited, in order (`["queued","running","done"]`).
    pub history: Vec<&'static str>,
    pub error: Option<String>,
    /// Terminal summary (evaluations, outcome, tenant env stats, ...).
    pub summary: Option<Json>,
    /// Progress in the method's natural unit.
    pub done: u64,
    pub total: u64,
    /// Replayed from `server.jsonl` after a daemon restart.
    pub restored: bool,
}

struct Inner {
    records: BTreeMap<u64, ExpRecord>,
    next_id: u64,
}

/// The experiment table + meta-journal + watch subscriptions.
pub struct Registry {
    dir: PathBuf,
    journal: Journal,
    inner: Mutex<Inner>,
    watchers: Mutex<Vec<(u64, Sender<Json>)>>,
}

impl Registry {
    /// Open (or create) a state directory, replaying `server.jsonl`:
    /// terminal experiments come back as-is, non-terminal ones return to
    /// `queued` with `restored` set so the scheduler re-runs them from
    /// their own checkpoint journals.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join("server.jsonl");
        let mut records: BTreeMap<u64, ExpRecord> = BTreeMap::new();
        let mut next_id = 1u64;
        if path.exists() {
            for rec in Journal::load(&path)? {
                let id = match rec.get("id").and_then(Json::as_f64) {
                    Some(f) => f as u64,
                    None => continue,
                };
                match rec.get("kind").and_then(Json::as_str) {
                    Some("exp") => {
                        let argv = rec
                            .get("argv")
                            .and_then(Json::as_arr)
                            .map(|a| {
                                a.iter()
                                    .filter_map(Json::as_str)
                                    .map(str::to_string)
                                    .collect()
                            })
                            .unwrap_or_default();
                        records.insert(
                            id,
                            ExpRecord {
                                id,
                                tenant: rec
                                    .get("tenant")
                                    .and_then(Json::as_str)
                                    .unwrap_or("default")
                                    .to_string(),
                                weight: rec
                                    .get("weight")
                                    .and_then(Json::as_f64)
                                    .map(|f| f as u64)
                                    .unwrap_or(1)
                                    .max(1),
                                run: rec
                                    .get("run")
                                    .and_then(Json::as_str)
                                    .unwrap_or("")
                                    .to_string(),
                                argv,
                                state: ExpState::Queued,
                                history: vec!["queued"],
                                error: None,
                                summary: None,
                                done: 0,
                                total: 0,
                                restored: true,
                            },
                        );
                        next_id = next_id.max(id + 1);
                    }
                    Some("exp_state") => {
                        if let Some(r) = records.get_mut(&id) {
                            if let Some(state) = rec
                                .get("state")
                                .and_then(Json::as_str)
                                .and_then(ExpState::parse)
                            {
                                r.state = state;
                                r.history = vec!["queued", "running", state.as_str()];
                            }
                            r.error = rec
                                .get("error")
                                .and_then(Json::as_str)
                                .map(str::to_string);
                            r.summary = rec.get("summary").cloned();
                        }
                    }
                    _ => {}
                }
            }
        }
        let journal = Journal::append_to(&path)?;
        Ok(Registry {
            dir,
            journal,
            inner: Mutex::new(Inner { records, next_id }),
            watchers: Mutex::new(Vec::new()),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Per-experiment file paths — keyed by the unique id, so concurrent
    /// experiments can never collide on names.
    pub fn csv_path(&self, id: u64) -> String {
        self.dir.join(format!("exp-{id}.csv")).to_string_lossy().into_owned()
    }

    pub fn journal_path(&self, id: u64) -> String {
        self.dir.join(format!("exp-{id}.jsonl")).to_string_lossy().into_owned()
    }

    pub fn result_path(&self, id: u64) -> String {
        self.dir
            .join(format!("exp-{id}.result.jsonl"))
            .to_string_lossy()
            .into_owned()
    }

    /// Register a new experiment (journaled), returning its id.
    pub fn submit(
        &self,
        tenant: &str,
        weight: u64,
        run: &str,
        argv: Vec<String>,
    ) -> Result<u64> {
        let id = {
            let mut inner = self.inner.lock().unwrap();
            let id = inner.next_id;
            inner.next_id += 1;
            inner.records.insert(
                id,
                ExpRecord {
                    id,
                    tenant: tenant.to_string(),
                    weight: weight.max(1),
                    run: run.to_string(),
                    argv: argv.clone(),
                    state: ExpState::Queued,
                    history: vec!["queued"],
                    error: None,
                    summary: None,
                    done: 0,
                    total: 0,
                    restored: false,
                },
            );
            id
        };
        self.journal.append(&obj(vec![
            ("kind", Json::Str("exp".into())),
            ("id", Json::Num(id as f64)),
            ("tenant", Json::Str(tenant.to_string())),
            ("weight", Json::Num(weight.max(1) as f64)),
            ("run", Json::Str(run.to_string())),
            (
                "argv",
                Json::Arr(argv.into_iter().map(Json::Str).collect()),
            ),
        ]))?;
        self.emit_state(id, ExpState::Queued, None);
        Ok(id)
    }

    /// Mark an experiment running (not journaled — a replayed run returns
    /// to `queued` and is re-run).
    pub fn set_running(&self, id: u64) {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(r) = inner.records.get_mut(&id) {
                if r.state.is_terminal() {
                    return;
                }
                r.state = ExpState::Running;
                r.history.push("running");
            }
        }
        self.emit_state(id, ExpState::Running, None);
    }

    /// Record a terminal state (journaled). A second terminal transition
    /// is ignored — cancel/finish races resolve to whichever lands first.
    pub fn finish(
        &self,
        id: u64,
        state: ExpState,
        error: Option<String>,
        summary: Option<Json>,
    ) -> Result<()> {
        debug_assert!(state.is_terminal());
        {
            let mut inner = self.inner.lock().unwrap();
            let Some(r) = inner.records.get_mut(&id) else {
                return Ok(());
            };
            if r.state.is_terminal() {
                return Ok(());
            }
            r.state = state;
            r.history.push(state.as_str());
            r.error = error.clone();
            r.summary = summary.clone();
        }
        let mut fields = vec![
            ("kind", Json::Str("exp_state".into())),
            ("id", Json::Num(id as f64)),
            ("state", Json::Str(state.as_str().into())),
        ];
        if let Some(e) = &error {
            fields.push(("error", Json::Str(e.clone())));
        }
        if let Some(s) = summary {
            fields.push(("summary", s));
        }
        self.journal.append(&obj(fields))?;
        self.emit_state(id, state, error);
        Ok(())
    }

    /// Update progress and notify watchers.
    pub fn progress(&self, id: u64, done: u64, total: u64) {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(r) = inner.records.get_mut(&id) {
                r.done = done;
                r.total = total;
            }
        }
        self.emit(
            id,
            obj(vec![
                ("event", Json::Str("progress".into())),
                ("id", Json::Num(id as f64)),
                ("done", Json::Num(done as f64)),
                ("total", Json::Num(total as f64)),
            ]),
        );
    }

    pub fn get(&self, id: u64) -> Option<ExpRecord> {
        self.inner.lock().unwrap().records.get(&id).cloned()
    }

    pub fn list(&self) -> Vec<ExpRecord> {
        self.inner.lock().unwrap().records.values().cloned().collect()
    }

    /// Ids still queued (ascending) — the scheduler's restart re-enqueue.
    pub fn queued_ids(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| r.state == ExpState::Queued)
            .map(|r| r.id)
            .collect()
    }

    /// Experiments not yet terminal (admission-control pressure).
    pub fn active_count(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| !r.state.is_terminal())
            .count()
    }

    /// Subscribe to an experiment's events. The receiver gets every
    /// `state`/`progress` event emitted after this call; dead receivers
    /// are pruned on the next emit.
    pub fn subscribe(&self, id: u64) -> Receiver<Json> {
        let (tx, rx) = channel();
        self.watchers.lock().unwrap().push((id, tx));
        rx
    }

    fn emit_state(&self, id: u64, state: ExpState, error: Option<String>) {
        let mut fields = vec![
            ("event", Json::Str("state".into())),
            ("id", Json::Num(id as f64)),
            ("state", Json::Str(state.as_str().into())),
        ];
        if let Some(e) = error {
            fields.push(("error", Json::Str(e)));
        }
        self.emit(id, obj(fields));
    }

    fn emit(&self, id: u64, event: Json) {
        let mut ws = self.watchers.lock().unwrap();
        ws.retain(|(wid, tx)| *wid != id || tx.send(event.clone()).is_ok());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "molers-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn replay_restores_terminal_and_requeues_unfinished() {
        let dir = tmp_dir("replay");
        {
            let reg = Registry::open(&dir).unwrap();
            let a = reg
                .submit("alice", 1, "explore", vec!["explore".into(), "--n".into(), "9".into()])
                .unwrap();
            let b = reg.submit("bob", 2, "calibrate", vec!["calibrate".into()]).unwrap();
            reg.set_running(a);
            reg.set_running(b);
            reg.finish(b, ExpState::Done, None, Some(Json::Num(1.0))).unwrap();
            assert_eq!(a, 1);
            assert_eq!(b, 2);
        }
        // "restart": replay the same directory
        let reg = Registry::open(&dir).unwrap();
        let a = reg.get(1).unwrap();
        assert_eq!(a.state, ExpState::Queued, "unfinished run returns to queued");
        assert!(a.restored);
        assert_eq!(a.argv, vec!["explore", "--n", "9"]);
        let b = reg.get(2).unwrap();
        assert_eq!(b.state, ExpState::Done, "terminal record wins");
        assert_eq!(b.summary, Some(Json::Num(1.0)));
        assert_eq!(reg.queued_ids(), vec![1]);
        // ids continue past the replayed maximum
        let c = reg.submit("carol", 1, "run", vec!["run".into()]).unwrap();
        assert_eq!(c, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn double_finish_keeps_the_first_terminal_state() {
        let dir = tmp_dir("double");
        let reg = Registry::open(&dir).unwrap();
        let id = reg.submit("t", 1, "run", vec!["run".into()]).unwrap();
        reg.finish(id, ExpState::Cancelled, Some("cancelled".into()), None).unwrap();
        reg.finish(id, ExpState::Failed, Some("late error".into()), None).unwrap();
        let r = reg.get(id).unwrap();
        assert_eq!(r.state, ExpState::Cancelled);
        assert_eq!(r.error.as_deref(), Some("cancelled"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn watchers_receive_events_after_subscribing() {
        let dir = tmp_dir("watch");
        let reg = Registry::open(&dir).unwrap();
        let id = reg.submit("t", 1, "run", vec!["run".into()]).unwrap();
        let rx = reg.subscribe(id);
        reg.set_running(id);
        reg.progress(id, 3, 10);
        reg.finish(id, ExpState::Done, None, None).unwrap();
        let kinds: Vec<String> = rx
            .try_iter()
            .map(|e| {
                format!(
                    "{}:{}",
                    e.get("event").and_then(Json::as_str).unwrap_or("?"),
                    e.get("state")
                        .or_else(|| e.get("done"))
                        .map(|v| v.to_string())
                        .unwrap_or_default()
                )
            })
            .collect();
        assert_eq!(
            kinds,
            vec!["state:\"running\"", "progress:3", "state:\"done\""]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
