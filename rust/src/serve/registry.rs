//! The server's experiment table: id allocation, state transitions, the
//! segmented `server.jsonl` meta-journal that makes them replayable, and
//! the seq-numbered event fan-out behind `watch`.
//!
//! Two record kinds are journaled (same line format as every other
//! journal in the crate):
//!
//! ```text
//! {"kind":"exp","id":3,"tenant":"alice","weight":2,"run":"explore",
//!  "argv":["explore","--n","200"],"dedup_key":"job-7"}    at submission
//! {"kind":"exp_state","id":3,"state":"done","summary":{...}}  terminal only
//! ```
//!
//! Intermediate states (`running`, progress) are deliberately *not*
//! journaled: on replay a non-terminal experiment simply returns to
//! `queued` and the scheduler re-runs it — resuming from its own
//! per-experiment checkpoint journal where one exists. Terminal records
//! win over re-submissions, so a finished experiment is never re-run.
//!
//! # Segments and compaction
//!
//! The meta-journal is a sequence of segments: `server.jsonl` (segment
//! 0, the name a fresh directory starts with) followed by
//! `server.N.jsonl` for N ≥ 1. Replay folds every segment in ascending
//! order. Startup compaction: when more than one segment exists, the
//! folded table is rewritten as a single snapshot segment
//! (`server.(max+1).jsonl`, written atomically via temp + rename) and
//! the old segments are deleted — so a long-lived daemon's replay stays
//! O(live experiments), not O(history). A long *run* also rolls: after
//! `roll_every` appends the same snapshot-then-delete step runs in
//! place. A crash at any point between those steps is safe because
//! replay is idempotent: a snapshot's `exp` line re-inserts the record
//! and its `exp_state` line re-applies the terminal state.
//!
//! # Durability
//!
//! Appends go through [`Journal`] under a [`Durability`] policy
//! (default [`Durability::Always`] for the server: `sync_data` per
//! record *before* the daemon acknowledges, so an acknowledged
//! submission or terminal state survives power loss).
//!
//! # Events
//!
//! Every emitted `state`/`progress` event carries a monotone `seq`
//! (global across experiments, starting at 1). The registry keeps a
//! bounded in-memory log of recent events; [`Registry::subscribe`] with
//! `after_seq` replays the missed tail to a reconnecting watcher — or
//! flags a `gap` when the tail has been evicted, in which case the
//! caller re-snapshots.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use crate::broker::journal::{self, Durability, Journal};
use crate::error::Result;
use crate::serve::protocol::obj;
use crate::util::json::Json;

/// Appends between mid-run meta-journal rolls.
const DEFAULT_ROLL_EVERY: usize = 4096;
/// Bounded event-log capacity (evicted seqs force watchers to
/// re-snapshot instead of replaying).
const EVENT_BUF_CAP: usize = 1024;

/// Lifecycle of one served experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpState {
    Queued,
    Running,
    Done,
    /// Finished, but some rows carry NaN objectives (`--degraded-ok`) or
    /// the run was restored without a usable checkpoint after a restart.
    Degraded,
    Failed,
    Cancelled,
}

impl ExpState {
    pub fn as_str(self) -> &'static str {
        match self {
            ExpState::Queued => "queued",
            ExpState::Running => "running",
            ExpState::Done => "done",
            ExpState::Degraded => "degraded",
            ExpState::Failed => "failed",
            ExpState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "queued" => ExpState::Queued,
            "running" => ExpState::Running,
            "done" => ExpState::Done,
            "degraded" => ExpState::Degraded,
            "failed" => ExpState::Failed,
            "cancelled" => ExpState::Cancelled,
            _ => return None,
        })
    }

    /// No further transitions once reached.
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            ExpState::Done | ExpState::Degraded | ExpState::Failed | ExpState::Cancelled
        )
    }
}

/// One experiment's full record.
#[derive(Debug, Clone)]
pub struct ExpRecord {
    pub id: u64,
    pub tenant: String,
    pub weight: u64,
    /// Method name (`run|explore|replicate|calibrate|island`).
    pub run: String,
    /// Sanitized CLI argv the server re-parses to build the experiment
    /// (journaled, so a restart rebuilds the identical configuration).
    pub argv: Vec<String>,
    /// Client-supplied idempotency key (journaled, so dedup survives a
    /// restart too).
    pub dedup_key: Option<String>,
    pub state: ExpState,
    /// States visited, in order (`["queued","running","done"]`).
    pub history: Vec<&'static str>,
    pub error: Option<String>,
    /// Terminal summary (evaluations, outcome, tenant env stats, ...).
    pub summary: Option<Json>,
    /// Progress in the method's natural unit.
    pub done: u64,
    pub total: u64,
    /// Replayed from the meta-journal after a daemon restart.
    pub restored: bool,
}

struct Inner {
    records: BTreeMap<u64, ExpRecord>,
    /// `(tenant, dedup_key)` → experiment id.
    dedup: HashMap<(String, String), u64>,
    next_id: u64,
}

/// The open meta-journal segment plus its roll bookkeeping.
struct MetaJournal {
    journal: Journal,
    seg_no: u64,
    appended: usize,
}

/// Seq-numbered event log + live watch subscriptions.
struct Events {
    /// Next seq to assign (first event gets 1).
    next_seq: u64,
    /// Highest seq evicted from `buf` (0 = nothing evicted yet).
    evicted_through: u64,
    buf: VecDeque<Json>,
    watchers: Vec<(u64, Sender<Json>)>,
}

/// One watch subscription: the live channel plus whatever the bounded
/// event log could replay for `after_seq`.
pub struct WatchSub {
    pub rx: Receiver<Json>,
    /// Buffered events for this experiment with `seq > after_seq`, in
    /// order. Empty when subscribing without a resume point.
    pub replay: Vec<Json>,
    /// `after_seq` predates the bounded log — the caller must
    /// re-snapshot instead of trusting `replay` to be complete.
    pub gap: bool,
    /// Highest seq assigned before this subscription (for seeding a
    /// fresh watcher's resume point).
    pub last_seq: u64,
}

/// The experiment table + meta-journal + watch subscriptions.
pub struct Registry {
    dir: PathBuf,
    durability: Durability,
    roll_every: usize,
    meta: Mutex<MetaJournal>,
    inner: Mutex<Inner>,
    events: Mutex<Events>,
}

/// Segment N's file name (`server.jsonl` for N = 0).
fn seg_name(n: u64) -> String {
    if n == 0 {
        "server.jsonl".to_string()
    } else {
        format!("server.{n}.jsonl")
    }
}

/// All meta-journal segments in `dir`, ascending by segment number.
fn meta_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if name == "server.jsonl" {
            segs.push((0u64, entry.path()));
        } else if let Some(mid) = name
            .strip_prefix("server.")
            .and_then(|s| s.strip_suffix(".jsonl"))
        {
            if let Ok(n) = mid.parse::<u64>() {
                segs.push((n, entry.path()));
            }
        }
    }
    segs.sort_by_key(|(n, _)| *n);
    Ok(segs)
}

/// Fold one segment's records into the table (tolerates a torn tail —
/// [`Journal::load`] drops incomplete last lines).
fn replay_segment(
    path: &Path,
    records: &mut BTreeMap<u64, ExpRecord>,
    dedup: &mut HashMap<(String, String), u64>,
    next_id: &mut u64,
) -> Result<()> {
    for rec in Journal::load(path)? {
        let id = match rec.get("id").and_then(Json::as_f64) {
            Some(f) => f as u64,
            None => continue,
        };
        match rec.get("kind").and_then(Json::as_str) {
            Some("exp") => {
                let argv = rec
                    .get("argv")
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .unwrap_or_default();
                let tenant = rec
                    .get("tenant")
                    .and_then(Json::as_str)
                    .unwrap_or("default")
                    .to_string();
                let dedup_key = rec
                    .get("dedup_key")
                    .and_then(Json::as_str)
                    .map(str::to_string);
                if let Some(k) = &dedup_key {
                    dedup.insert((tenant.clone(), k.clone()), id);
                }
                records.insert(
                    id,
                    ExpRecord {
                        id,
                        tenant,
                        weight: rec
                            .get("weight")
                            .and_then(Json::as_f64)
                            .map(|f| f as u64)
                            .unwrap_or(1)
                            .max(1),
                        run: rec
                            .get("run")
                            .and_then(Json::as_str)
                            .unwrap_or("")
                            .to_string(),
                        argv,
                        dedup_key,
                        state: ExpState::Queued,
                        history: vec!["queued"],
                        error: None,
                        summary: None,
                        done: 0,
                        total: 0,
                        restored: true,
                    },
                );
                *next_id = (*next_id).max(id + 1);
            }
            Some("exp_state") => {
                if let Some(r) = records.get_mut(&id) {
                    if let Some(state) = rec
                        .get("state")
                        .and_then(Json::as_str)
                        .and_then(ExpState::parse)
                    {
                        r.state = state;
                        r.history = vec!["queued", "running", state.as_str()];
                    }
                    r.error = rec
                        .get("error")
                        .and_then(Json::as_str)
                        .map(str::to_string);
                    r.summary = rec.get("summary").cloned();
                }
            }
            _ => {}
        }
    }
    Ok(())
}

/// The journal line registering an experiment.
fn exp_json(r: &ExpRecord) -> Json {
    let mut fields = vec![
        ("kind", Json::Str("exp".into())),
        ("id", Json::Num(r.id as f64)),
        ("tenant", Json::Str(r.tenant.clone())),
        ("weight", Json::Num(r.weight as f64)),
        ("run", Json::Str(r.run.clone())),
        (
            "argv",
            Json::Arr(r.argv.iter().cloned().map(Json::Str).collect()),
        ),
    ];
    if let Some(k) = &r.dedup_key {
        fields.push(("dedup_key", Json::Str(k.clone())));
    }
    obj(fields)
}

/// The journal line recording a terminal state.
fn exp_state_json(r: &ExpRecord) -> Json {
    let mut fields = vec![
        ("kind", Json::Str("exp_state".into())),
        ("id", Json::Num(r.id as f64)),
        ("state", Json::Str(r.state.as_str().into())),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::Str(e.clone())));
    }
    if let Some(s) = &r.summary {
        fields.push(("summary", s.clone()));
    }
    obj(fields)
}

/// The folded table as snapshot-segment bytes: one `exp` line per
/// record, plus an `exp_state` line where the state is terminal.
fn snapshot_body(records: &BTreeMap<u64, ExpRecord>) -> String {
    let mut body = String::new();
    for r in records.values() {
        body.push_str(&exp_json(r).to_string());
        body.push('\n');
        if r.state.is_terminal() {
            body.push_str(&exp_state_json(r).to_string());
            body.push('\n');
        }
    }
    body
}

impl Registry {
    /// Open (or create) a state directory with the server's defaults:
    /// fsync-per-record durability (an acknowledged record survives
    /// power loss) and the standard roll threshold. Replays every
    /// meta-journal segment: terminal experiments come back as-is,
    /// non-terminal ones return to `queued` with `restored` set so the
    /// scheduler re-runs them from their own checkpoint journals.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_tuned(dir, Durability::Always, DEFAULT_ROLL_EVERY)
    }

    /// [`Registry::open`] with an explicit durability policy.
    pub fn open_with(dir: impl AsRef<Path>, durability: Durability) -> Result<Self> {
        Self::open_tuned(dir, durability, DEFAULT_ROLL_EVERY)
    }

    /// Fully-tuned open (tests use a tiny `roll_every` to exercise
    /// segment rolls without thousands of submissions).
    pub fn open_tuned(
        dir: impl AsRef<Path>,
        durability: Durability,
        roll_every: usize,
    ) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        journal::fsync_dir(&dir);
        let mut segs = meta_segments(&dir)?;
        let mut records: BTreeMap<u64, ExpRecord> = BTreeMap::new();
        let mut dedup: HashMap<(String, String), u64> = HashMap::new();
        let mut next_id = 1u64;
        for (_, path) in &segs {
            replay_segment(path, &mut records, &mut dedup, &mut next_id)?;
        }
        // startup compaction: fold multiple segments into one snapshot
        let (seg_no, path) = if segs.len() > 1 {
            let new_no = segs.last().unwrap().0 + 1;
            let snap = dir.join(seg_name(new_no));
            journal::atomic_write(&snap, snapshot_body(&records).as_bytes())?;
            // the snapshot is durable — history is now redundant
            for (_, old) in &segs {
                let _ = std::fs::remove_file(old);
            }
            journal::fsync_dir(&dir);
            (new_no, snap)
        } else if let Some((n, p)) = segs.pop() {
            (n, p)
        } else {
            (0, dir.join(seg_name(0)))
        };
        let jour = Journal::append_to_with(&path, durability)?;
        Ok(Registry {
            dir,
            durability,
            roll_every: roll_every.max(1),
            meta: Mutex::new(MetaJournal {
                journal: jour,
                seg_no,
                appended: 0,
            }),
            inner: Mutex::new(Inner {
                records,
                dedup,
                next_id,
            }),
            events: Mutex::new(Events {
                next_seq: 1,
                evicted_through: 0,
                buf: VecDeque::new(),
                watchers: Vec::new(),
            }),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append to the meta-journal, rolling to a fresh snapshot segment
    /// once this one has grown past the threshold. Lock order is always
    /// meta → inner (briefly, for the snapshot); callers hold neither.
    fn append_meta(&self, rec: &Json) -> Result<()> {
        let mut m = self.meta.lock().unwrap();
        m.journal.append(rec)?;
        m.appended += 1;
        if m.appended >= self.roll_every {
            let body = {
                let inner = self.inner.lock().unwrap();
                snapshot_body(&inner.records)
            };
            let new_no = m.seg_no + 1;
            let snap = self.dir.join(seg_name(new_no));
            journal::atomic_write(&snap, body.as_bytes())?;
            let old = self.dir.join(seg_name(m.seg_no));
            let _ = std::fs::remove_file(&old);
            journal::fsync_dir(&self.dir);
            m.journal = Journal::append_to_with(&snap, self.durability)?;
            m.seg_no = new_no;
            m.appended = 0;
        }
        Ok(())
    }

    /// Per-experiment file paths — keyed by the unique id, so concurrent
    /// experiments can never collide on names.
    pub fn csv_path(&self, id: u64) -> String {
        self.dir.join(format!("exp-{id}.csv")).to_string_lossy().into_owned()
    }

    pub fn journal_path(&self, id: u64) -> String {
        self.dir.join(format!("exp-{id}.jsonl")).to_string_lossy().into_owned()
    }

    pub fn result_path(&self, id: u64) -> String {
        self.dir
            .join(format!("exp-{id}.result.jsonl"))
            .to_string_lossy()
            .into_owned()
    }

    /// Provenance manifest written next to the result once the
    /// experiment reaches `done`/`degraded` (see `crate::provenance`).
    pub fn manifest_path(&self, id: u64) -> String {
        self.dir
            .join(format!("exp-{id}.manifest.json"))
            .to_string_lossy()
            .into_owned()
    }

    /// Durable pareto front for evolution methods, in the deterministic
    /// front-file format shared with the CLI and `molers reexec`.
    pub fn front_path(&self, id: u64) -> String {
        self.dir
            .join(format!("exp-{id}.front.jsonl"))
            .to_string_lossy()
            .into_owned()
    }

    /// Where a budgeted explore pages its out-of-core rows. Under the
    /// state dir (never a client-chosen path), keyed by id like every
    /// other per-experiment file.
    pub fn spill_dir(&self, id: u64) -> String {
        self.dir
            .join(format!("exp-{id}.spill"))
            .to_string_lossy()
            .into_owned()
    }

    /// An existing experiment for `(tenant, dedup_key)`, if any — the
    /// fast path a retried submit takes before admission control.
    pub fn dedup_lookup(&self, tenant: &str, key: &str) -> Option<u64> {
        self.inner
            .lock()
            .unwrap()
            .dedup
            .get(&(tenant.to_string(), key.to_string()))
            .copied()
    }

    /// Register a new experiment (journaled durably before returning),
    /// returning `(id, fresh)`. When `dedup_key` matches an earlier
    /// submission by the same tenant, the original id comes back with
    /// `fresh = false` and nothing is journaled or enqueued — the
    /// check-and-insert is atomic under the table lock, so two racing
    /// retries can never both register.
    pub fn submit(
        &self,
        tenant: &str,
        weight: u64,
        run: &str,
        argv: Vec<String>,
        dedup_key: Option<&str>,
    ) -> Result<(u64, bool)> {
        let rec = {
            let mut inner = self.inner.lock().unwrap();
            if let Some(k) = dedup_key {
                if let Some(&id) =
                    inner.dedup.get(&(tenant.to_string(), k.to_string()))
                {
                    return Ok((id, false));
                }
            }
            let id = inner.next_id;
            inner.next_id += 1;
            let rec = ExpRecord {
                id,
                tenant: tenant.to_string(),
                weight: weight.max(1),
                run: run.to_string(),
                argv,
                dedup_key: dedup_key.map(str::to_string),
                state: ExpState::Queued,
                history: vec!["queued"],
                error: None,
                summary: None,
                done: 0,
                total: 0,
                restored: false,
            };
            if let Some(k) = dedup_key {
                inner.dedup.insert((tenant.to_string(), k.to_string()), id);
            }
            inner.records.insert(id, rec.clone());
            rec
        };
        let id = rec.id;
        self.append_meta(&exp_json(&rec))?;
        self.emit_state(id, ExpState::Queued, None);
        Ok((id, true))
    }

    /// Mark an experiment running (not journaled — a replayed run returns
    /// to `queued` and is re-run).
    pub fn set_running(&self, id: u64) {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(r) = inner.records.get_mut(&id) {
                if r.state.is_terminal() {
                    return;
                }
                r.state = ExpState::Running;
                r.history.push("running");
            }
        }
        self.emit_state(id, ExpState::Running, None);
    }

    /// Record a terminal state (journaled durably before returning). A
    /// second terminal transition is ignored — cancel/finish races
    /// resolve to whichever lands first.
    pub fn finish(
        &self,
        id: u64,
        state: ExpState,
        error: Option<String>,
        summary: Option<Json>,
    ) -> Result<()> {
        debug_assert!(state.is_terminal());
        let rec = {
            let mut inner = self.inner.lock().unwrap();
            let Some(r) = inner.records.get_mut(&id) else {
                return Ok(());
            };
            if r.state.is_terminal() {
                return Ok(());
            }
            r.state = state;
            r.history.push(state.as_str());
            r.error = error.clone();
            r.summary = summary;
            r.clone()
        };
        self.append_meta(&exp_state_json(&rec))?;
        self.emit_state(id, state, error);
        Ok(())
    }

    /// Update progress and notify watchers.
    pub fn progress(&self, id: u64, done: u64, total: u64) {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(r) = inner.records.get_mut(&id) {
                r.done = done;
                r.total = total;
            }
        }
        self.emit(
            id,
            obj(vec![
                ("event", Json::Str("progress".into())),
                ("id", Json::Num(id as f64)),
                ("done", Json::Num(done as f64)),
                ("total", Json::Num(total as f64)),
            ]),
        );
    }

    pub fn get(&self, id: u64) -> Option<ExpRecord> {
        self.inner.lock().unwrap().records.get(&id).cloned()
    }

    pub fn list(&self) -> Vec<ExpRecord> {
        self.inner.lock().unwrap().records.values().cloned().collect()
    }

    /// Ids still queued (ascending) — the scheduler's restart re-enqueue.
    pub fn queued_ids(&self) -> Vec<u64> {
        self.inner
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| r.state == ExpState::Queued)
            .map(|r| r.id)
            .collect()
    }

    /// Experiments not yet terminal (admission-control pressure).
    pub fn active_count(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .records
            .values()
            .filter(|r| !r.state.is_terminal())
            .count()
    }

    /// Subscribe to an experiment's events. The receiver gets every
    /// `state`/`progress` event emitted after this call; dead receivers
    /// are pruned on the next emit. With `after_seq`, buffered events
    /// newer than that seq come back in [`WatchSub::replay`] — or
    /// [`WatchSub::gap`] is set when the bounded log has already evicted
    /// part of the requested tail. Subscription and replay extraction
    /// are atomic under the event lock, so no event can fall between
    /// the replayed tail and the live channel.
    pub fn subscribe(&self, id: u64, after_seq: Option<u64>) -> WatchSub {
        let mut ev = self.events.lock().unwrap();
        let (tx, rx) = channel();
        let last_seq = ev.next_seq - 1;
        let (replay, gap) = match after_seq {
            None => (Vec::new(), false),
            Some(after) => {
                let gap = after < ev.evicted_through;
                let replay = ev
                    .buf
                    .iter()
                    .filter(|e| {
                        e.get("id").and_then(Json::as_f64).map(|f| f as u64)
                            == Some(id)
                            && e.get("seq")
                                .and_then(Json::as_f64)
                                .map(|f| f as u64)
                                .unwrap_or(0)
                                > after
                    })
                    .cloned()
                    .collect();
                (replay, gap)
            }
        };
        ev.watchers.push((id, tx));
        WatchSub {
            rx,
            replay,
            gap,
            last_seq,
        }
    }

    /// Highest seq assigned so far (0 = no events yet).
    pub fn last_seq(&self) -> u64 {
        self.events.lock().unwrap().next_seq - 1
    }

    fn emit_state(&self, id: u64, state: ExpState, error: Option<String>) {
        let mut fields = vec![
            ("event", Json::Str("state".into())),
            ("id", Json::Num(id as f64)),
            ("state", Json::Str(state.as_str().into())),
        ];
        if let Some(e) = error {
            fields.push(("error", Json::Str(e)));
        }
        self.emit(id, obj(fields));
    }

    /// Stamp the next seq onto the event, log it, fan it out.
    fn emit(&self, id: u64, mut event: Json) {
        let mut ev = self.events.lock().unwrap();
        let seq = ev.next_seq;
        ev.next_seq += 1;
        if let Json::Obj(m) = &mut event {
            m.insert("seq".to_string(), Json::Num(seq as f64));
        }
        ev.buf.push_back(event.clone());
        while ev.buf.len() > EVENT_BUF_CAP {
            if let Some(old) = ev.buf.pop_front() {
                let s = old
                    .get("seq")
                    .and_then(Json::as_f64)
                    .map(|f| f as u64)
                    .unwrap_or(0);
                ev.evicted_through = ev.evicted_through.max(s);
            }
        }
        ev.watchers
            .retain(|(wid, tx)| *wid != id || tx.send(event.clone()).is_ok());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "molers-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn replay_restores_terminal_and_requeues_unfinished() {
        let dir = tmp_dir("replay");
        {
            let reg = Registry::open(&dir).unwrap();
            let (a, _) = reg
                .submit(
                    "alice",
                    1,
                    "explore",
                    vec!["explore".into(), "--n".into(), "9".into()],
                    None,
                )
                .unwrap();
            let (b, _) = reg
                .submit("bob", 2, "calibrate", vec!["calibrate".into()], None)
                .unwrap();
            reg.set_running(a);
            reg.set_running(b);
            reg.finish(b, ExpState::Done, None, Some(Json::Num(1.0))).unwrap();
            assert_eq!(a, 1);
            assert_eq!(b, 2);
        }
        // "restart": replay the same directory
        let reg = Registry::open(&dir).unwrap();
        let a = reg.get(1).unwrap();
        assert_eq!(a.state, ExpState::Queued, "unfinished run returns to queued");
        assert!(a.restored);
        assert_eq!(a.argv, vec!["explore", "--n", "9"]);
        let b = reg.get(2).unwrap();
        assert_eq!(b.state, ExpState::Done, "terminal record wins");
        assert_eq!(b.summary, Some(Json::Num(1.0)));
        assert_eq!(reg.queued_ids(), vec![1]);
        // ids continue past the replayed maximum
        let (c, _) = reg.submit("carol", 1, "run", vec!["run".into()], None).unwrap();
        assert_eq!(c, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn double_finish_keeps_the_first_terminal_state() {
        let dir = tmp_dir("double");
        let reg = Registry::open(&dir).unwrap();
        let (id, _) = reg.submit("t", 1, "run", vec!["run".into()], None).unwrap();
        reg.finish(id, ExpState::Cancelled, Some("cancelled".into()), None).unwrap();
        reg.finish(id, ExpState::Failed, Some("late error".into()), None).unwrap();
        let r = reg.get(id).unwrap();
        assert_eq!(r.state, ExpState::Cancelled);
        assert_eq!(r.error.as_deref(), Some("cancelled"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn watchers_receive_events_after_subscribing() {
        let dir = tmp_dir("watch");
        let reg = Registry::open(&dir).unwrap();
        let (id, _) = reg.submit("t", 1, "run", vec!["run".into()], None).unwrap();
        let sub = reg.subscribe(id, None);
        assert!(sub.replay.is_empty());
        reg.set_running(id);
        reg.progress(id, 3, 10);
        reg.finish(id, ExpState::Done, None, None).unwrap();
        let events: Vec<Json> = sub.rx.try_iter().collect();
        let kinds: Vec<String> = events
            .iter()
            .map(|e| {
                format!(
                    "{}:{}",
                    e.get("event").and_then(Json::as_str).unwrap_or("?"),
                    e.get("state")
                        .or_else(|| e.get("done"))
                        .map(|v| v.to_string())
                        .unwrap_or_default()
                )
            })
            .collect();
        assert_eq!(
            kinds,
            vec!["state:\"running\"", "progress:3", "state:\"done\""]
        );
        // every event carries a strictly increasing seq
        let seqs: Vec<u64> = events
            .iter()
            .map(|e| e.get("seq").and_then(Json::as_f64).unwrap() as u64)
            .collect();
        assert!(seqs.windows(2).all(|w| w[1] > w[0]));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dedup_key_returns_the_original_id_even_across_restart() {
        let dir = tmp_dir("dedup");
        {
            let reg = Registry::open(&dir).unwrap();
            let (a, fresh) = reg
                .submit("alice", 1, "run", vec!["run".into()], Some("job-7"))
                .unwrap();
            assert!(fresh);
            let (a2, fresh2) = reg
                .submit("alice", 1, "run", vec!["run".into()], Some("job-7"))
                .unwrap();
            assert_eq!(a2, a, "same tenant + key dedups");
            assert!(!fresh2);
            // a different tenant's identical key is a different namespace
            let (b, fresh3) = reg
                .submit("bob", 1, "run", vec!["run".into()], Some("job-7"))
                .unwrap();
            assert_ne!(b, a);
            assert!(fresh3);
            assert_eq!(reg.dedup_lookup("alice", "job-7"), Some(a));
            assert_eq!(reg.dedup_lookup("alice", "other"), None);
        }
        // the key is journaled: a restarted daemon still dedups
        let reg = Registry::open(&dir).unwrap();
        let (a3, fresh4) = reg
            .submit("alice", 1, "run", vec!["run".into()], Some("job-7"))
            .unwrap();
        assert_eq!(a3, 1);
        assert!(!fresh4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn subscribe_with_after_seq_replays_the_missed_tail() {
        let dir = tmp_dir("afterseq");
        let reg = Registry::open(&dir).unwrap();
        let (id, _) = reg.submit("t", 1, "run", vec!["run".into()], None).unwrap();
        let first = reg.subscribe(id, None);
        reg.set_running(id);
        let seen: Vec<Json> = first.rx.try_iter().collect();
        let last = seen
            .last()
            .and_then(|e| e.get("seq"))
            .and_then(Json::as_f64)
            .unwrap() as u64;
        // "the connection dropped": more transitions land meanwhile
        reg.progress(id, 5, 10);
        reg.finish(id, ExpState::Done, None, None).unwrap();
        let sub = reg.subscribe(id, Some(last));
        assert!(!sub.gap);
        let replayed: Vec<&str> = sub
            .replay
            .iter()
            .map(|e| e.get("event").and_then(Json::as_str).unwrap())
            .collect();
        assert_eq!(replayed, vec!["progress", "state"], "missed tail replays");
        let seqs: Vec<u64> = sub
            .replay
            .iter()
            .map(|e| e.get("seq").and_then(Json::as_f64).unwrap() as u64)
            .collect();
        assert!(seqs.iter().all(|&s| s > last));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn roll_compacts_segments_and_replay_folds_them() {
        let dir = tmp_dir("roll");
        {
            // tiny roll threshold: every few appends rewrites a snapshot
            let reg = Registry::open_tuned(&dir, Durability::Os, 3).unwrap();
            for i in 0..5 {
                let (id, _) = reg
                    .submit("t", 1, "run", vec!["run".into()], None)
                    .unwrap();
                assert_eq!(id, i + 1);
            }
            reg.finish(2, ExpState::Done, None, Some(Json::Num(2.0))).unwrap();
            reg.finish(4, ExpState::Failed, Some("boom".into()), None).unwrap();
        }
        let segs = meta_segments(&dir).unwrap();
        assert!(
            !segs.is_empty(),
            "at least one live segment remains after rolls"
        );
        // a reopened registry folds whatever segments exist back into
        // the identical table
        let reg = Registry::open_tuned(&dir, Durability::Os, 4096).unwrap();
        assert_eq!(reg.list().len(), 5);
        assert_eq!(reg.get(2).unwrap().state, ExpState::Done);
        assert_eq!(reg.get(2).unwrap().summary, Some(Json::Num(2.0)));
        assert_eq!(reg.get(4).unwrap().state, ExpState::Failed);
        assert_eq!(reg.get(4).unwrap().error.as_deref(), Some("boom"));
        assert_eq!(reg.queued_ids(), vec![1, 3, 5]);
        // rolls delete superseded segments as they go
        let segs = meta_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1, "a roll leaves a single live snapshot");
        // and ids keep climbing past everything replayed
        let (next, _) = reg.submit("t", 1, "run", vec!["run".into()], None).unwrap();
        assert_eq!(next, 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn startup_compaction_folds_a_crash_torn_segment_pair() {
        // a crash between snapshot-write and old-segment-delete leaves
        // two overlapping segments on disk — exactly what this builds
        let dir = tmp_dir("compact");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("server.jsonl"),
            "{\"kind\":\"exp\",\"id\":1,\"tenant\":\"t\",\"weight\":1,\
             \"run\":\"run\",\"argv\":[\"run\"],\"dedup_key\":\"k1\"}\n\
             {\"kind\":\"exp\",\"id\":2,\"tenant\":\"t\",\"weight\":1,\
             \"run\":\"run\",\"argv\":[\"run\"]}\n\
             {\"kind\":\"exp_state\",\"id\":1,\"state\":\"done\"}\n",
        )
        .unwrap();
        // the snapshot segment re-states everything (replay idempotence)
        std::fs::write(
            dir.join("server.1.jsonl"),
            "{\"kind\":\"exp\",\"id\":1,\"tenant\":\"t\",\"weight\":1,\
             \"run\":\"run\",\"argv\":[\"run\"],\"dedup_key\":\"k1\"}\n\
             {\"kind\":\"exp_state\",\"id\":1,\"state\":\"done\"}\n\
             {\"kind\":\"exp\",\"id\":2,\"tenant\":\"t\",\"weight\":1,\
             \"run\":\"run\",\"argv\":[\"run\"]}\n",
        )
        .unwrap();
        let reg = Registry::open_with(&dir, Durability::Os).unwrap();
        assert_eq!(reg.get(1).unwrap().state, ExpState::Done);
        assert_eq!(reg.get(2).unwrap().state, ExpState::Queued);
        assert_eq!(reg.dedup_lookup("t", "k1"), Some(1));
        let segs = meta_segments(&dir).unwrap();
        assert_eq!(segs.len(), 1, "compaction folded both into one snapshot");
        assert_eq!(segs[0].0, 2, "snapshot numbers past the newest segment");
        // the folded snapshot replays to the same table again
        drop(reg);
        let reg = Registry::open_with(&dir, Durability::Os).unwrap();
        assert_eq!(reg.get(1).unwrap().state, ExpState::Done);
        assert_eq!(reg.queued_ids(), vec![2]);
        let (next, _) = reg.submit("t", 1, "run", vec!["run".into()], None).unwrap();
        assert_eq!(next, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
