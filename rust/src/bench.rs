//! Micro-bench harness for the `cargo bench` targets (criterion is not
//! vendored in this image — DESIGN.md §3). Provides warmup, repeated
//! timed runs and robust summary statistics, printed in a stable
//! `name ... median=…` format that EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

use crate::util::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} median={} mean={} sd={} (n={})",
            self.name,
            human(self.median_s()),
            human(self.mean_s()),
            human(self.stddev_s()),
            self.samples.len()
        )
    }
}

fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Bench runner: `Bench::new("e1").case("pjrt", || {...})`.
pub struct Bench {
    suite: String,
    warmup: u32,
    samples: u32,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        Bench {
            suite: suite.to_string(),
            warmup: 1,
            samples: 5,
            results: Vec::new(),
        }
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: u32) -> Self {
        self.samples = n;
        self
    }

    /// Time `f` (already containing its own inner loop if wanted).
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: format!("{}/{}", self.suite, name),
            samples,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally computed metric (e.g. virtual throughput).
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {value:.1} {unit}", format!("{}/{}", self.suite, name));
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Time one closure once (for coarse end-to-end numbers).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench::new("t").warmup(0).samples(3);
        let m = b.case("noop", || 1 + 1);
        assert_eq!(m.samples.len(), 3);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(2.0), "2.000s");
        assert_eq!(human(0.002), "2.000ms");
        assert_eq!(human(2e-6), "2.000µs");
        assert_eq!(human(5e-9), "5ns");
    }
}
