//! Micro-bench harness for the `cargo bench` targets (criterion is not
//! vendored in this image — DESIGN.md §3). Provides warmup, repeated
//! timed runs and robust summary statistics, printed in a stable
//! `name ... median=…` format that EXPERIMENTS.md quotes, plus a JSON
//! writer (`BENCH_<suite>.json`) so the perf trajectory is machine-read
//! across PRs.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<f64>, // seconds per iteration
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn mean_s(&self) -> f64 {
        stats::mean(&self.samples)
    }

    pub fn stddev_s(&self) -> f64 {
        stats::stddev(&self.samples)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} median={} mean={} sd={} (n={})",
            self.name,
            human(self.median_s()),
            human(self.mean_s()),
            human(self.stddev_s()),
            self.samples.len()
        )
    }
}

fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Bench runner: `Bench::new("e1").case("pjrt", || {...})`.
pub struct Bench {
    suite: String,
    warmup: u32,
    samples: u32,
    results: Vec<Measurement>,
    metrics: Vec<(String, f64, String)>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        Bench {
            suite: suite.to_string(),
            warmup: 1,
            samples: 5,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: u32) -> Self {
        self.samples = n;
        self
    }

    /// Time `f` (already containing its own inner loop if wanted).
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let m = Measurement {
            name: format!("{}/{}", self.suite, name),
            samples,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally computed metric (e.g. virtual throughput).
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<44} {value:.1} {unit}", format!("{}/{}", self.suite, name));
        self.metrics
            .push((name.to_string(), value, unit.to_string()));
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// The suite as a JSON document: every timed case (name, median_s,
    /// mean_s, sd, n) plus the recorded metrics.
    pub fn json(&self) -> Json {
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                let mut case = BTreeMap::new();
                case.insert("name".to_string(), Json::Str(m.name.clone()));
                case.insert("median_s".to_string(), Json::Num(m.median_s()));
                case.insert("mean_s".to_string(), Json::Num(m.mean_s()));
                case.insert("sd".to_string(), Json::Num(m.stddev_s()));
                case.insert("n".to_string(), Json::Num(m.samples.len() as f64));
                Json::Obj(case)
            })
            .collect();
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|(name, value, unit)| {
                let mut metric = BTreeMap::new();
                metric.insert(
                    "name".to_string(),
                    Json::Str(format!("{}/{}", self.suite, name)),
                );
                metric.insert("value".to_string(), Json::Num(*value));
                metric.insert("unit".to_string(), Json::Str(unit.clone()));
                Json::Obj(metric)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("suite".to_string(), Json::Str(self.suite.clone()));
        root.insert("cases".to_string(), Json::Arr(cases));
        root.insert("metrics".to_string(), Json::Arr(metrics));
        Json::Obj(root)
    }

    /// Write `BENCH_<suite>.json` into `$BENCH_OUT_DIR` (default: the
    /// working directory) and return its path. Benches call this last so
    /// every run leaves a machine-readable record next to the repo.
    pub fn write_json(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var_os("BENCH_OUT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        self.write_json_to(&dir)
    }

    /// Write `BENCH_<suite>.json` into an explicit directory.
    pub fn write_json_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.suite));
        std::fs::write(&path, self.json().to_string())?;
        println!("bench json: {}", path.display());
        Ok(path)
    }
}

/// Time one closure once (for coarse end-to-end numbers).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench::new("t").warmup(0).samples(3);
        let m = b.case("noop", || 1 + 1);
        assert_eq!(m.samples.len(), 3);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn json_round_trips_cases_and_metrics() {
        let mut b = Bench::new("suite_x").warmup(0).samples(4);
        b.case("work", || 2 + 2);
        b.metric("speedup", 3.5, "x");
        let doc = crate::util::json::parse(&b.json().to_string()).unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("suite_x"));
        let cases = doc.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(
            cases[0].get("name").unwrap().as_str(),
            Some("suite_x/work")
        );
        assert_eq!(cases[0].get("n").unwrap().as_usize(), Some(4));
        assert!(cases[0].get("median_s").unwrap().as_f64().unwrap() >= 0.0);
        let metrics = doc.get("metrics").unwrap().as_arr().unwrap();
        assert_eq!(metrics[0].get("value").unwrap().as_f64(), Some(3.5));
    }

    #[test]
    fn write_json_lands_in_requested_dir() {
        // write_json_to, not write_json: mutating BENCH_OUT_DIR via
        // set_var would race other tests reading the environment
        let dir = std::env::temp_dir().join("molers_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut b = Bench::new("wj").warmup(0).samples(2);
        b.case("noop", || ());
        let path = b.write_json_to(&dir).unwrap();
        assert_eq!(path, dir.join("BENCH_wj.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::parse(&text).is_ok());
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(2.0), "2.000s");
        assert_eq!(human(0.002), "2.000ms");
        assert_eq!(human(2e-6), "2.000µs");
        assert_eq!(human(5e-9), "5ns");
    }
}
