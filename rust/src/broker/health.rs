//! Per-backend health tracking and circuit breaking.
//!
//! Each backend keeps a sliding window of recent attempt outcomes. When
//! the windowed failure rate crosses a threshold the backend is
//! *quarantined*: the broker stops routing new work to it for a cooldown
//! measured in dispatch decisions (a deterministic clock that advances
//! whether or not virtual time does). When the cooldown expires the
//! window is cleared, so the backend re-enters service with a clean slate
//! and one bad century ago doesn't keep re-tripping the breaker
//! (half-open probing).
//!
//! An attempt the broker abandons on a [`crate::broker::RetryPolicy`]
//! real-time bound (attempt timeout on a hung backend) is recorded here
//! as a failure exactly like a lost submission — a backend that hangs
//! jobs drains its health window and trips the breaker the same way one
//! that drops them does.

use std::collections::VecDeque;

/// Circuit-breaker thresholds.
#[derive(Debug, Clone)]
pub struct CircuitConfig {
    /// Outcomes remembered per backend.
    pub window: usize,
    /// Minimum outcomes in the window before the breaker may trip.
    pub min_samples: usize,
    /// Windowed failure rate at/above which the breaker trips.
    pub failure_threshold: f64,
    /// Dispatch decisions a tripped backend sits out.
    pub cooldown_dispatches: u32,
}

impl Default for CircuitConfig {
    fn default() -> Self {
        CircuitConfig {
            window: 32,
            min_samples: 8,
            failure_threshold: 0.5,
            cooldown_dispatches: 16,
        }
    }
}

/// Health state of one backend.
#[derive(Debug, Default)]
pub struct Health {
    outcomes: VecDeque<bool>, // true = success
    failures_in_window: usize,
    cooldown: u32,
    /// Times the breaker has tripped over the backend's lifetime.
    pub trips: u64,
}

impl Health {
    /// Record one attempt outcome; trips the breaker when the window is
    /// both full enough and bad enough.
    pub fn record(&mut self, success: bool, cfg: &CircuitConfig) {
        if self.outcomes.len() == cfg.window.max(1) {
            if let Some(old) = self.outcomes.pop_front() {
                if !old {
                    self.failures_in_window -= 1;
                }
            }
        }
        self.outcomes.push_back(success);
        if !success {
            self.failures_in_window += 1;
        }
        if self.cooldown == 0
            && self.outcomes.len() >= cfg.min_samples.max(1)
            && self.failure_rate() >= cfg.failure_threshold
        {
            self.cooldown = cfg.cooldown_dispatches;
            self.trips += 1;
        }
    }

    /// Advance the quarantine clock by one dispatch decision. On expiry
    /// the outcome window resets (half-open: the next attempts decide).
    pub fn tick(&mut self) {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            if self.cooldown == 0 {
                self.outcomes.clear();
                self.failures_in_window = 0;
            }
        }
    }

    pub fn quarantined(&self) -> bool {
        self.cooldown > 0
    }

    /// Windowed failure rate (0.0 while the window is empty).
    pub fn failure_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            0.0
        } else {
            self.failures_in_window as f64 / self.outcomes.len() as f64
        }
    }

    /// Windowed success rate (1.0 while the window is empty).
    pub fn success_rate(&self) -> f64 {
        1.0 - self.failure_rate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CircuitConfig {
        CircuitConfig {
            window: 8,
            min_samples: 4,
            failure_threshold: 0.5,
            cooldown_dispatches: 3,
        }
    }

    #[test]
    fn healthy_backend_never_trips() {
        let mut h = Health::default();
        for _ in 0..100 {
            h.record(true, &cfg());
        }
        assert!(!h.quarantined());
        assert_eq!(h.trips, 0);
        assert_eq!(h.success_rate(), 1.0);
    }

    #[test]
    fn failure_spike_trips_and_cooldown_releases() {
        let mut h = Health::default();
        for _ in 0..4 {
            h.record(false, &cfg());
        }
        assert!(h.quarantined(), "4/4 failures must trip at threshold 0.5");
        assert_eq!(h.trips, 1);
        h.tick();
        h.tick();
        assert!(h.quarantined());
        h.tick();
        assert!(!h.quarantined(), "cooldown of 3 dispatches expired");
        // half-open: the window was cleared on release
        assert_eq!(h.failure_rate(), 0.0);
    }

    #[test]
    fn needs_min_samples_before_tripping() {
        let mut h = Health::default();
        h.record(false, &cfg());
        h.record(false, &cfg());
        h.record(false, &cfg());
        assert!(!h.quarantined(), "3 < min_samples, must not trip yet");
    }

    #[test]
    fn window_slides() {
        let mut h = Health::default();
        let c = cfg();
        for _ in 0..8 {
            h.record(false, &c);
        }
        // flush the cooldown so old failures can age out
        for _ in 0..3 {
            h.tick();
        }
        for _ in 0..8 {
            h.record(true, &c);
        }
        assert_eq!(h.failure_rate(), 0.0, "old failures aged out of the window");
        assert!(!h.quarantined());
    }

    #[test]
    fn recovered_backend_can_trip_again() {
        let mut h = Health::default();
        let c = cfg();
        for _ in 0..4 {
            h.record(false, &c);
        }
        for _ in 0..3 {
            h.tick();
        }
        for _ in 0..4 {
            h.record(false, &c);
        }
        assert_eq!(h.trips, 2);
    }
}
