//! Dispatch policies: how the [`crate::broker::Broker`] picks a backend
//! for each job.
//!
//! A policy sees one [`BackendView`] per *eligible* backend (quarantined
//! and explicitly excluded backends are filtered out before the call) and
//! returns an index into that slice. Policies must be deterministic given
//! the views — all load-adaptivity enters through the view fields, which
//! the broker keeps up to date on every dispatch and completion.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::rng::splitmix64;

/// Time-bounded retry semantics, enforced in the broker's waiter state
/// machine: how many attempts a job gets, how long (in **real** seconds)
/// the broker waits on any one attempt before abandoning it as hung, how
/// long the whole job may take across attempts, and how re-dispatches
/// back off in **virtual** time.
///
/// The two timelines matter: backends here are discrete-event simulations
/// around real local compute, and a hung job never produces a virtual
/// report — so the only clock that can bound it is the real one. Backoff,
/// by contrast, delays the *simulated* resubmission, so it is virtual.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts per job, the first dispatch included.
    pub max_attempts: u32,
    /// Real seconds to wait on one attempt before abandoning it as hung
    /// (health-penalised and re-routed like any infrastructure failure).
    pub attempt_timeout_s: f64,
    /// Real seconds the whole job may take across all attempts; past it
    /// the job fails terminally with [`crate::error::Error::Timeout`].
    pub job_deadline_s: f64,
    /// Base of the exponential virtual backoff: retry `k` is released
    /// `backoff_base_s · 2^(k-1)` virtual seconds after the failure.
    pub backoff_base_s: f64,
    /// Ceiling on any single backoff step.
    pub backoff_max_s: f64,
    /// Jitter fraction in `[0, 1]`: each backoff step is scaled by a
    /// deterministic per-`(job, attempt)` factor in `[1-j, 1+j)`, so a
    /// wave of same-instant failures does not re-dispatch in lockstep.
    pub jitter: f64,
}

impl Default for RetryPolicy {
    /// 4 attempts, 600 s per attempt, 3600 s per job, backoff
    /// 30 s → 480 s with ±50 % jitter.
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            attempt_timeout_s: 600.0,
            job_deadline_s: 3600.0,
            backoff_base_s: 30.0,
            backoff_max_s: 480.0,
            jitter: 0.5,
        }
    }
}

impl RetryPolicy {
    /// Virtual seconds to back off before re-dispatching after failed
    /// attempt number `attempt` (1-based). The jitter is a pure function
    /// of `(seed, job_index, attempt)`, so a resumed or replayed run
    /// reproduces the exact same schedule.
    pub fn backoff_s(&self, attempt: u32, seed: u64, job_index: u64) -> f64 {
        let exp = 2f64.powi(attempt.saturating_sub(1).min(32) as i32);
        let base = (self.backoff_base_s * exp).min(self.backoff_max_s.max(self.backoff_base_s));
        let mut h = seed
            ^ job_index.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ u64::from(attempt).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let u = (splitmix64(&mut h) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let j = self.jitter.clamp(0.0, 1.0);
        base * (1.0 - j + 2.0 * j * u)
    }
}

/// A backend as the policy sees it at dispatch time.
#[derive(Debug, Clone)]
pub struct BackendView {
    /// Index of this backend in the broker's backend table.
    pub backend: usize,
    /// Capacity hint (node/slot count) given at registration.
    pub capacity: usize,
    /// Jobs dispatched to this backend and not yet resolved.
    pub in_flight: usize,
    /// Attempts completed successfully on this backend.
    pub completed: u64,
    /// EWMA of virtual submit+exec seconds per successful attempt
    /// (0.0 until the first completion).
    pub ewma_duration_s: f64,
    /// Successes / attempts over the recent outcome window (1.0 while the
    /// window is empty).
    pub success_rate: f64,
}

/// Picks one of the eligible backends for the next job.
pub trait DispatchPolicy: Send + Sync {
    fn name(&self) -> &str;

    /// Return an index into `views` (not a backend id). `views` is never
    /// empty.
    fn choose(&self, views: &[BackendView]) -> usize;
}

/// Cycle through backends in registration order, skipping nothing.
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &str {
        "round-robin"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % views.len()
    }
}

/// Send each job to the backend with the fewest unresolved dispatches.
#[derive(Default)]
pub struct LeastInFlight;

impl LeastInFlight {
    pub fn new() -> Self {
        LeastInFlight
    }
}

impl DispatchPolicy for LeastInFlight {
    fn name(&self) -> &str {
        "least-in-flight"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        views
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.in_flight
                    .cmp(&b.in_flight)
                    .then(a.backend.cmp(&b.backend))
            })
            .map(|(i, _)| i)
            .expect("views is never empty")
    }
}

/// Throughput/latency-aware policy: score every backend by its expected
/// completion time for one more job and pick the minimum.
///
/// `score = ewma_duration · (1 + in_flight / capacity) / success_rate`
///
/// * the EWMA tracks how long one attempt takes on that backend
///   (submission latency + node execution, in virtual seconds);
/// * the `(1 + in_flight/capacity)` factor models queue depth per slot, so
///   the policy reacts to its own dispatches before completions arrive;
/// * dividing by the recent success rate makes flaky backends expensive in
///   proportion to how much work they lose.
///
/// Until a backend has completed anything its EWMA is unknown; those
/// backends use the fleet-wide mean duration (or 1.0 s before any
/// completion at all), which makes the cold-start phase behave like
/// capacity-weighted least-loaded while the EWMA warms up.
#[derive(Default)]
pub struct EwmaPolicy;

impl EwmaPolicy {
    pub fn new() -> Self {
        EwmaPolicy
    }
}

impl DispatchPolicy for EwmaPolicy {
    fn name(&self) -> &str {
        "ewma"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        let sampled: Vec<f64> = views
            .iter()
            .filter(|v| v.completed > 0)
            .map(|v| v.ewma_duration_s)
            .collect();
        let fleet_mean = if sampled.is_empty() {
            1.0
        } else {
            sampled.iter().sum::<f64>() / sampled.len() as f64
        };
        views
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                score(a, fleet_mean)
                    .total_cmp(&score(b, fleet_mean))
                    .then(a.backend.cmp(&b.backend))
            })
            .map(|(i, _)| i)
            .expect("views is never empty")
    }
}

fn score(v: &BackendView, fleet_mean: f64) -> f64 {
    let duration = if v.completed > 0 {
        v.ewma_duration_s
    } else {
        fleet_mean
    };
    let queue = 1.0 + v.in_flight as f64 / v.capacity.max(1) as f64;
    duration * queue / v.success_rate.max(0.05)
}

/// Look a policy up by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn DispatchPolicy>> {
    match name {
        "roundrobin" | "round-robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least" | "least-in-flight" => Some(Box::new(LeastInFlight::new())),
        "ewma" => Some(Box::new(EwmaPolicy::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(backend: usize, in_flight: usize, ewma: f64, completed: u64) -> BackendView {
        BackendView {
            backend,
            capacity: 4,
            in_flight,
            completed,
            ewma_duration_s: ewma,
            success_rate: 1.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let p = RoundRobin::new();
        let views = vec![view(0, 0, 0.0, 0), view(1, 0, 0.0, 0), view(2, 0, 0.0, 0)];
        let picks: Vec<usize> = (0..6).map(|_| p.choose(&views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_in_flight_picks_idle() {
        let p = LeastInFlight::new();
        let views = vec![view(0, 5, 0.0, 0), view(1, 2, 0.0, 0), view(2, 7, 0.0, 0)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn ewma_prefers_fast_backend() {
        let p = EwmaPolicy::new();
        // equal load, backend 1 is 3× faster
        let views = vec![view(0, 2, 30.0, 10), view(1, 2, 10.0, 10)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn ewma_backs_off_loaded_backend() {
        let p = EwmaPolicy::new();
        // backend 1 is faster per job but its queue is far deeper
        let views = vec![view(0, 0, 20.0, 10), view(1, 40, 10.0, 10)];
        assert_eq!(p.choose(&views), 0);
    }

    #[test]
    fn ewma_penalises_flaky_backend() {
        let p = EwmaPolicy::new();
        let mut a = view(0, 1, 10.0, 10);
        let mut b = view(1, 1, 10.0, 10);
        a.success_rate = 1.0;
        b.success_rate = 0.5; // loses half its work → effectively 2× slower
        assert_eq!(p.choose(&[a, b]), 0);
    }

    #[test]
    fn ewma_cold_start_spreads_by_load() {
        let p = EwmaPolicy::new();
        // nothing completed anywhere: behave like least-loaded
        let views = vec![view(0, 3, 0.0, 0), view(1, 1, 0.0, 0)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn backoff_is_exponential_capped_and_deterministically_jittered() {
        let p = RetryPolicy {
            jitter: 0.0,
            ..RetryPolicy::default()
        };
        assert_eq!(p.backoff_s(1, 0, 0), 30.0);
        assert_eq!(p.backoff_s(2, 0, 0), 60.0);
        assert_eq!(p.backoff_s(3, 0, 0), 120.0);
        assert_eq!(p.backoff_s(10, 0, 0), 480.0, "capped at backoff_max_s");

        let j = RetryPolicy::default(); // jitter 0.5
        let a = j.backoff_s(2, 42, 7);
        assert_eq!(a, j.backoff_s(2, 42, 7), "same (seed, job, attempt) → same delay");
        assert_ne!(a, j.backoff_s(2, 42, 8), "different job → different jitter");
        assert!(
            (30.0..90.0).contains(&a),
            "step 2 with ±50% jitter stays in [30, 90): {a}"
        );
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("ewma").unwrap().name(), "ewma");
        assert_eq!(by_name("rr").unwrap().name(), "round-robin");
        assert_eq!(by_name("least").unwrap().name(), "least-in-flight");
        assert!(by_name("nope").is_none());
    }
}
