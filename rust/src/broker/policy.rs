//! Dispatch policies: how the [`crate::broker::Broker`] picks a backend
//! for each job.
//!
//! A policy sees one [`BackendView`] per *eligible* backend (quarantined
//! and explicitly excluded backends are filtered out before the call) and
//! returns an index into that slice. Policies must be deterministic given
//! the views — all load-adaptivity enters through the view fields, which
//! the broker keeps up to date on every dispatch and completion.

use std::sync::atomic::{AtomicUsize, Ordering};

/// A backend as the policy sees it at dispatch time.
#[derive(Debug, Clone)]
pub struct BackendView {
    /// Index of this backend in the broker's backend table.
    pub backend: usize,
    /// Capacity hint (node/slot count) given at registration.
    pub capacity: usize,
    /// Jobs dispatched to this backend and not yet resolved.
    pub in_flight: usize,
    /// Attempts completed successfully on this backend.
    pub completed: u64,
    /// EWMA of virtual submit+exec seconds per successful attempt
    /// (0.0 until the first completion).
    pub ewma_duration_s: f64,
    /// Successes / attempts over the recent outcome window (1.0 while the
    /// window is empty).
    pub success_rate: f64,
}

/// Picks one of the eligible backends for the next job.
pub trait DispatchPolicy: Send + Sync {
    fn name(&self) -> &str;

    /// Return an index into `views` (not a backend id). `views` is never
    /// empty.
    fn choose(&self, views: &[BackendView]) -> usize;
}

/// Cycle through backends in registration order, skipping nothing.
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for RoundRobin {
    fn name(&self) -> &str {
        "round-robin"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % views.len()
    }
}

/// Send each job to the backend with the fewest unresolved dispatches.
#[derive(Default)]
pub struct LeastInFlight;

impl LeastInFlight {
    pub fn new() -> Self {
        LeastInFlight
    }
}

impl DispatchPolicy for LeastInFlight {
    fn name(&self) -> &str {
        "least-in-flight"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        views
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.in_flight
                    .cmp(&b.in_flight)
                    .then(a.backend.cmp(&b.backend))
            })
            .map(|(i, _)| i)
            .expect("views is never empty")
    }
}

/// Throughput/latency-aware policy: score every backend by its expected
/// completion time for one more job and pick the minimum.
///
/// `score = ewma_duration · (1 + in_flight / capacity) / success_rate`
///
/// * the EWMA tracks how long one attempt takes on that backend
///   (submission latency + node execution, in virtual seconds);
/// * the `(1 + in_flight/capacity)` factor models queue depth per slot, so
///   the policy reacts to its own dispatches before completions arrive;
/// * dividing by the recent success rate makes flaky backends expensive in
///   proportion to how much work they lose.
///
/// Until a backend has completed anything its EWMA is unknown; those
/// backends use the fleet-wide mean duration (or 1.0 s before any
/// completion at all), which makes the cold-start phase behave like
/// capacity-weighted least-loaded while the EWMA warms up.
#[derive(Default)]
pub struct EwmaPolicy;

impl EwmaPolicy {
    pub fn new() -> Self {
        EwmaPolicy
    }
}

impl DispatchPolicy for EwmaPolicy {
    fn name(&self) -> &str {
        "ewma"
    }

    fn choose(&self, views: &[BackendView]) -> usize {
        let sampled: Vec<f64> = views
            .iter()
            .filter(|v| v.completed > 0)
            .map(|v| v.ewma_duration_s)
            .collect();
        let fleet_mean = if sampled.is_empty() {
            1.0
        } else {
            sampled.iter().sum::<f64>() / sampled.len() as f64
        };
        views
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                score(a, fleet_mean)
                    .total_cmp(&score(b, fleet_mean))
                    .then(a.backend.cmp(&b.backend))
            })
            .map(|(i, _)| i)
            .expect("views is never empty")
    }
}

fn score(v: &BackendView, fleet_mean: f64) -> f64 {
    let duration = if v.completed > 0 {
        v.ewma_duration_s
    } else {
        fleet_mean
    };
    let queue = 1.0 + v.in_flight as f64 / v.capacity.max(1) as f64;
    duration * queue / v.success_rate.max(0.05)
}

/// Look a policy up by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn DispatchPolicy>> {
    match name {
        "roundrobin" | "round-robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least" | "least-in-flight" => Some(Box::new(LeastInFlight::new())),
        "ewma" => Some(Box::new(EwmaPolicy::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(backend: usize, in_flight: usize, ewma: f64, completed: u64) -> BackendView {
        BackendView {
            backend,
            capacity: 4,
            in_flight,
            completed,
            ewma_duration_s: ewma,
            success_rate: 1.0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let p = RoundRobin::new();
        let views = vec![view(0, 0, 0.0, 0), view(1, 0, 0.0, 0), view(2, 0, 0.0, 0)];
        let picks: Vec<usize> = (0..6).map(|_| p.choose(&views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_in_flight_picks_idle() {
        let p = LeastInFlight::new();
        let views = vec![view(0, 5, 0.0, 0), view(1, 2, 0.0, 0), view(2, 7, 0.0, 0)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn ewma_prefers_fast_backend() {
        let p = EwmaPolicy::new();
        // equal load, backend 1 is 3× faster
        let views = vec![view(0, 2, 30.0, 10), view(1, 2, 10.0, 10)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn ewma_backs_off_loaded_backend() {
        let p = EwmaPolicy::new();
        // backend 1 is faster per job but its queue is far deeper
        let views = vec![view(0, 0, 20.0, 10), view(1, 40, 10.0, 10)];
        assert_eq!(p.choose(&views), 0);
    }

    #[test]
    fn ewma_penalises_flaky_backend() {
        let p = EwmaPolicy::new();
        let mut a = view(0, 1, 10.0, 10);
        let mut b = view(1, 1, 10.0, 10);
        a.success_rate = 1.0;
        b.success_rate = 0.5; // loses half its work → effectively 2× slower
        assert_eq!(p.choose(&[a, b]), 0);
    }

    #[test]
    fn ewma_cold_start_spreads_by_load() {
        let p = EwmaPolicy::new();
        // nothing completed anywhere: behave like least-loaded
        let views = vec![view(0, 3, 0.0, 0), view(1, 1, 0.0, 0)];
        assert_eq!(p.choose(&views), 1);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("ewma").unwrap().name(), "ewma");
        assert_eq!(by_name("rr").unwrap().name(), "round-robin");
        assert_eq!(by_name("least").unwrap().name(), "least-in-flight");
        assert!(by_name("nope").is_none());
    }
}
