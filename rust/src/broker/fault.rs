//! Deterministic fault injection: wrap any environment so a fraction of
//! submissions is dropped before execution (the middleware "lost" the
//! job). This is how tests, the failover example and the `p3_broker`
//! bench build a misbehaving backend without touching the inner
//! environment's own failure model.

use std::sync::{Arc, Mutex};

use crate::environment::{EnvStats, Environment, Job, JobHandle};
use crate::error::Error;
use crate::util::Rng;

/// An [`Environment`] decorator that terminally fails each submission
/// with probability `failure_rate`, drawn from its own deterministic RNG
/// in submission order. Failed jobs never reach the inner environment —
/// the caller (normally the [`crate::broker::Broker`]) sees an immediate
/// [`Error::NodeFailure`] and is expected to re-route.
pub struct FlakyEnv {
    name: String,
    inner: Arc<dyn Environment>,
    failure_rate: f64,
    rng: Mutex<Rng>,
    injected: Mutex<u64>,
}

impl FlakyEnv {
    pub fn new(inner: Arc<dyn Environment>, failure_rate: f64, seed: u64) -> Self {
        FlakyEnv {
            name: format!("flaky[{:.0}%]:{}", failure_rate * 100.0, inner.name()),
            inner,
            failure_rate: failure_rate.clamp(0.0, 1.0),
            rng: Mutex::new(Rng::new(seed)),
            injected: Mutex::new(0),
        }
    }

    /// Submissions dropped so far.
    pub fn injected_failures(&self) -> u64 {
        *self.injected.lock().unwrap()
    }
}

impl Environment for FlakyEnv {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        let drop_it = self.rng.lock().unwrap().bool(self.failure_rate);
        if drop_it {
            *self.injected.lock().unwrap() += 1;
            return JobHandle::ready(Err(Error::NodeFailure {
                node: format!("{}/<lost>", self.name),
                reason: "submission dropped by injected fault".into(),
            }));
        }
        self.inner.submit(job)
    }

    fn stats(&self) -> EnvStats {
        // the inner environment never saw the dropped jobs; add them back
        // so this environment's ledger stays consistent
        let mut s = self.inner.stats();
        let injected = *self.injected.lock().unwrap();
        s.submitted += injected;
        s.failed_attempts += injected;
        s.failed_jobs += injected;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Context;
    use crate::dsl::task::ClosureTask;
    use crate::environment::local::LocalEnvironment;

    fn noop() -> Arc<ClosureTask> {
        Arc::new(ClosureTask::new("noop", |c: &Context| Ok(c.clone())))
    }

    #[test]
    fn injects_the_requested_failure_fraction() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(2)), 0.3, 5);
        let mut failures = 0u64;
        for _ in 0..200 {
            if env
                .submit(Job::new(noop(), Context::new()))
                .wait()
                .is_err()
            {
                failures += 1;
            }
        }
        assert!(
            (30..=90).contains(&failures),
            "expected ≈60 failures at 30%, got {failures}"
        );
        assert_eq!(env.injected_failures(), failures);
        let s = env.stats();
        assert_eq!(s.submitted, 200);
        assert_eq!(s.failed_jobs, failures);
        assert_eq!(s.completed, 200 - failures);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn zero_rate_is_transparent() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(1)), 0.0, 1);
        for _ in 0..20 {
            env.submit(Job::new(noop(), Context::new())).wait().unwrap();
        }
        assert_eq!(env.injected_failures(), 0);
    }

    #[test]
    fn failure_surfaces_as_node_failure() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(1)), 1.0, 1);
        let err = env
            .submit(Job::new(noop(), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::NodeFailure { .. }));
    }
}
