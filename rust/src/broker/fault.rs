//! Deterministic chaos injection: wrap any environment in a seeded
//! [`FaultPlan`] so tests, benches and the failover example can replay a
//! misbehaving grid without touching the inner environment's own failure
//! model. Every fault is drawn from the decorator's own [`Rng`] in
//! submission order, so a chaos run is reproducible from `(plan, seed)`.
//!
//! ## Fault modes
//!
//! * **drops** — the submission is "lost" by the middleware: the caller
//!   sees an immediate [`Error::NodeFailure`] and the inner environment
//!   never sees the job.
//! * **hangs** — the job is accepted but *never* completes: the handle's
//!   `try_wait` stays `None` forever. Only a broker-enforced
//!   [`RetryPolicy`](crate::broker::RetryPolicy) attempt timeout or job
//!   deadline bounds the wait.
//! * **stragglers** — the job completes, but its virtual execution time is
//!   stretched by a drawn delay (`delay_s × [0.5, 1.5)`), the classic
//!   grid long-tail that speculation is meant to cut.
//! * **crash windows** — a contiguous range of submission indices fails
//!   terminally (the backend "crashed"), after which it recovers.
//!
//! ## `FaultPlan` grammar
//!
//! [`FaultPlan::parse`] accepts clauses separated by `;` or `,`:
//!
//! ```text
//! drop=P          drop each submission with probability P
//! hang=P          hang each submission with probability P
//! delay=P:S       straggle with probability P by S × [0.5, 1.5) virtual s
//! crash=START+LEN fail submissions START..START+LEN terminally
//! ```
//!
//! e.g. `drop=0.2;hang=0.01;delay=0.1:60;crash=40+8`. The broker's
//! `--envs` spec accepts the same grammar after `~` (a bare number after
//! `~` keeps the historical drops-only meaning): `pbs:32~drop=0.2;hang=0.01`.
//!
//! ## Journal record kinds & retry defaults
//!
//! Degraded campaigns write a `degraded_rows` journal record (`rows`,
//! `clock`, `error`) next to the usual `sample_block` checkpoints — see
//! [`crate::broker::journal`]. The broker's time bounds default to
//! [`RetryPolicy::default`](crate::broker::RetryPolicy): 4 attempts,
//! 600 s per attempt, 3600 s per job, exponential backoff 30 s → 480 s
//! with ±50 % deterministic jitter.

use std::sync::{Arc, Mutex};

use crate::environment::{EnvStats, Environment, Job, JobHandle, JobWaiter};
use crate::error::Error;
use crate::util::Rng;

/// A contiguous range of submission indices during which the backend is
/// "crashed": submissions `start..start + len` fail terminally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashWindow {
    pub start: u64,
    pub len: u64,
}

impl CrashWindow {
    fn contains(&self, idx: u64) -> bool {
        idx >= self.start && idx - self.start < self.len
    }
}

/// A composable, seedable description of injectable faults (module doc
/// has the grammar). An empty plan is a transparent pass-through.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Probability a submission is dropped before execution.
    pub drop_rate: f64,
    /// Probability a submission hangs forever.
    pub hang_rate: f64,
    /// Probability a completed job is stretched into a straggler.
    pub straggler_rate: f64,
    /// Mean-ish straggler stretch: the injected delay is
    /// `straggler_delay_s × [0.5, 1.5)` virtual seconds.
    pub straggler_delay_s: f64,
    /// Crash-and-recover windows over the submission index sequence.
    pub crash_windows: Vec<CrashWindow>,
}

impl FaultPlan {
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Drop each submission with probability `p`.
    pub fn drops(mut self, p: f64) -> Self {
        self.drop_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Hang each submission with probability `p`.
    pub fn hangs(mut self, p: f64) -> Self {
        self.hang_rate = p.clamp(0.0, 1.0);
        self
    }

    /// Straggle with probability `p`, stretching virtual execution by
    /// `delay_s × [0.5, 1.5)` seconds.
    pub fn stragglers(mut self, p: f64, delay_s: f64) -> Self {
        self.straggler_rate = p.clamp(0.0, 1.0);
        self.straggler_delay_s = delay_s.max(0.0);
        self
    }

    /// Fail submissions `start..start + len` terminally (backend crash),
    /// then recover.
    pub fn crash_window(mut self, start: u64, len: u64) -> Self {
        self.crash_windows.push(CrashWindow { start, len });
        self
    }

    /// True when the plan injects nothing.
    pub fn is_empty(&self) -> bool {
        self.drop_rate == 0.0
            && self.hang_rate == 0.0
            && self.straggler_rate == 0.0
            && self.crash_windows.is_empty()
    }

    fn in_crash_window(&self, idx: u64) -> bool {
        self.crash_windows.iter().any(|w| w.contains(idx))
    }

    /// Parse the clause grammar documented in the module doc. Clauses are
    /// separated by `;` or `,`; unknown keys and malformed values are
    /// [`Error::Config`] errors.
    pub fn parse(spec: &str) -> crate::error::Result<FaultPlan> {
        let bad = |msg: String| Error::Config(format!("bad fault plan `{spec}`: {msg}"));
        let prob = |key: &str, v: &str| -> crate::error::Result<f64> {
            let p: f64 = v
                .parse()
                .map_err(|_| bad(format!("`{key}` needs a probability, got `{v}`")))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(bad(format!("`{key}` probability {p} outside [0, 1]")));
            }
            Ok(p)
        };
        let mut plan = FaultPlan::new();
        for clause in spec.split([';', ',']).filter(|c| !c.is_empty()) {
            let (key, value) = clause
                .split_once('=')
                .ok_or_else(|| bad(format!("clause `{clause}` is not `key=value`")))?;
            match key {
                "drop" => plan.drop_rate = prob(key, value)?,
                "hang" => plan.hang_rate = prob(key, value)?,
                "delay" => {
                    let (p, s) = value.split_once(':').ok_or_else(|| {
                        bad(format!("`delay` needs `P:SECONDS`, got `{value}`"))
                    })?;
                    plan.straggler_rate = prob(key, p)?;
                    plan.straggler_delay_s = s.parse().map_err(|_| {
                        bad(format!("`delay` seconds must be a number, got `{s}`"))
                    })?;
                }
                "crash" => {
                    let (start, len) = value.split_once('+').ok_or_else(|| {
                        bad(format!("`crash` needs `START+LEN`, got `{value}`"))
                    })?;
                    let parse_u64 = |t: &str| {
                        t.parse::<u64>().map_err(|_| {
                            bad(format!("`crash` bounds must be integers, got `{t}`"))
                        })
                    };
                    plan.crash_windows.push(CrashWindow {
                        start: parse_u64(start)?,
                        len: parse_u64(len)?,
                    });
                }
                other => return Err(bad(format!("unknown fault kind `{other}`"))),
            }
        }
        Ok(plan)
    }
}

impl std::fmt::Display for FaultPlan {
    /// Canonical clause form, re-parseable by [`FaultPlan::parse`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut clauses = Vec::new();
        if self.drop_rate > 0.0 {
            clauses.push(format!("drop={}", self.drop_rate));
        }
        if self.hang_rate > 0.0 {
            clauses.push(format!("hang={}", self.hang_rate));
        }
        if self.straggler_rate > 0.0 {
            clauses.push(format!(
                "delay={}:{}",
                self.straggler_rate, self.straggler_delay_s
            ));
        }
        for w in &self.crash_windows {
            clauses.push(format!("crash={}+{}", w.start, w.len));
        }
        write!(f, "{}", clauses.join(";"))
    }
}

/// Per-mode injection counters (see [`FaultyEnv::injected`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InjectedFaults {
    /// Submissions dropped before reaching the inner environment.
    pub drops: u64,
    /// Submissions that will never complete.
    pub hangs: u64,
    /// Completed jobs stretched by an injected delay.
    pub stragglers: u64,
    /// Submissions terminally failed inside a crash window.
    pub crash_failures: u64,
}

impl InjectedFaults {
    pub fn total(&self) -> u64 {
        self.drops + self.hangs + self.stragglers + self.crash_failures
    }
}

/// A handle that never completes: the injected "hung backend".
struct HungJob;

impl JobWaiter for HungJob {
    fn wait(self: Box<Self>) -> crate::error::Result<(crate::core::Context, crate::environment::JobReport)> {
        // only a broker deadline can unblock a hung job; waiting on the
        // raw handle really does block forever, as on a real grid
        loop {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
    fn try_wait(
        &self,
    ) -> Option<crate::error::Result<(crate::core::Context, crate::environment::JobReport)>> {
        None
    }
}

/// Wraps an inner handle, stretching the report's virtual execution time
/// by the drawn straggler delay.
struct DelayedJob {
    inner: JobHandle,
    delay_s: f64,
}

fn stretch(
    delay_s: f64,
    r: crate::error::Result<(crate::core::Context, crate::environment::JobReport)>,
) -> crate::error::Result<(crate::core::Context, crate::environment::JobReport)> {
    r.map(|(ctx, mut report)| {
        report.exec_s += delay_s;
        report.virtual_end += delay_s;
        (ctx, report)
    })
}

impl JobWaiter for DelayedJob {
    fn wait(
        self: Box<Self>,
    ) -> crate::error::Result<(crate::core::Context, crate::environment::JobReport)> {
        stretch(self.delay_s, self.inner.wait())
    }
    fn try_wait(
        &self,
    ) -> Option<crate::error::Result<(crate::core::Context, crate::environment::JobReport)>> {
        self.inner.try_wait().map(|r| stretch(self.delay_s, r))
    }
}

/// An [`Environment`] decorator executing a [`FaultPlan`]: faults are
/// drawn per submission, in submission order, from a seeded [`Rng`], so
/// any chaos run is reproducible from `(plan, seed)`.
pub struct FaultyEnv {
    name: String,
    inner: Arc<dyn Environment>,
    plan: FaultPlan,
    rng: Mutex<Rng>,
    submissions: Mutex<u64>,
    injected: Mutex<InjectedFaults>,
}

impl FaultyEnv {
    pub fn new(inner: Arc<dyn Environment>, plan: FaultPlan, seed: u64) -> Self {
        let name = if plan.is_empty() {
            format!("chaos[]:{}", inner.name())
        } else {
            format!("chaos[{plan}]:{}", inner.name())
        };
        FaultyEnv::named(inner, plan, seed, name)
    }

    fn named(inner: Arc<dyn Environment>, plan: FaultPlan, seed: u64, name: String) -> Self {
        FaultyEnv {
            name,
            inner,
            plan,
            rng: Mutex::new(Rng::new(seed)),
            submissions: Mutex::new(0),
            injected: Mutex::new(InjectedFaults::default()),
        }
    }

    /// Per-mode injection counters so far.
    pub fn injected(&self) -> InjectedFaults {
        *self.injected.lock().unwrap()
    }

    /// The plan this decorator executes.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl Environment for FaultyEnv {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        let idx = {
            let mut s = self.submissions.lock().unwrap();
            let i = *s;
            *s += 1;
            i
        };
        if self.plan.in_crash_window(idx) {
            self.injected.lock().unwrap().crash_failures += 1;
            return JobHandle::ready(Err(Error::NodeFailure {
                node: format!("{}/<crashed>", self.name),
                reason: format!("backend crash window (submission {idx})"),
            }));
        }
        // fixed draw order per submission — drop, hang, straggle, delay —
        // keeps the fault stream identical whatever each outcome is
        let (drop_it, hang_it, straggle, delay_u) = {
            let mut r = self.rng.lock().unwrap();
            (
                r.bool(self.plan.drop_rate),
                r.bool(self.plan.hang_rate),
                r.bool(self.plan.straggler_rate),
                r.f64(),
            )
        };
        if drop_it {
            self.injected.lock().unwrap().drops += 1;
            return JobHandle::ready(Err(Error::NodeFailure {
                node: format!("{}/<lost>", self.name),
                reason: "submission dropped by injected fault".into(),
            }));
        }
        if hang_it {
            self.injected.lock().unwrap().hangs += 1;
            return JobHandle::from_waiter(Box::new(HungJob));
        }
        let handle = self.inner.submit(job);
        if straggle {
            self.injected.lock().unwrap().stragglers += 1;
            let delay_s = self.plan.straggler_delay_s * (0.5 + delay_u);
            return JobHandle::from_waiter(Box::new(DelayedJob {
                inner: handle,
                delay_s,
            }));
        }
        handle
    }

    fn stats(&self) -> EnvStats {
        let mut s = self.inner.stats();
        let inj = self.injected();
        // dropped and crashed submissions never reached the inner
        // environment: fold them back in as submitted + terminally failed
        // so the ledger balances. Hung submissions are folded in as
        // submitted-but-unresolved — exactly what a hung backend looks
        // like from outside: they stay in `in_flight()` forever.
        let lost = inj.drops + inj.crash_failures;
        s.submitted += lost + inj.hangs;
        s.failed_attempts += lost;
        s.failed_jobs += lost;
        s.injected_faults += inj.total();
        s
    }
}

/// The historical single-mode decorator: terminally fail each submission
/// with probability `failure_rate`. Now a thin drops-only [`FaultPlan`]
/// over [`FaultyEnv`], kept for the `~p` spec shorthand and existing
/// callers.
pub struct FlakyEnv {
    inner: FaultyEnv,
}

impl FlakyEnv {
    pub fn new(inner: Arc<dyn Environment>, failure_rate: f64, seed: u64) -> Self {
        let name = format!("flaky[{:.0}%]:{}", failure_rate * 100.0, inner.name());
        FlakyEnv {
            inner: FaultyEnv::named(inner, FaultPlan::new().drops(failure_rate), seed, name),
        }
    }

    /// Submissions dropped so far.
    pub fn injected_failures(&self) -> u64 {
        self.inner.injected().drops
    }
}

impl Environment for FlakyEnv {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn submit(&self, job: Job) -> JobHandle {
        self.inner.submit(job)
    }

    fn stats(&self) -> EnvStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Context;
    use crate::dsl::task::ClosureTask;
    use crate::environment::local::LocalEnvironment;

    fn noop() -> Arc<ClosureTask> {
        Arc::new(ClosureTask::new("noop", |c: &Context| Ok(c.clone())))
    }

    #[test]
    fn injects_the_requested_failure_fraction() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(2)), 0.3, 5);
        let mut failures = 0u64;
        for _ in 0..200 {
            if env
                .submit(Job::new(noop(), Context::new()))
                .wait()
                .is_err()
            {
                failures += 1;
            }
        }
        assert!(
            (30..=90).contains(&failures),
            "expected ≈60 failures at 30%, got {failures}"
        );
        assert_eq!(env.injected_failures(), failures);
        let s = env.stats();
        assert_eq!(s.submitted, 200);
        assert_eq!(s.failed_jobs, failures);
        assert_eq!(s.completed, 200 - failures);
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.injected_faults, failures);
    }

    #[test]
    fn zero_rate_is_transparent() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(1)), 0.0, 1);
        for _ in 0..20 {
            env.submit(Job::new(noop(), Context::new())).wait().unwrap();
        }
        assert_eq!(env.injected_failures(), 0);
    }

    #[test]
    fn failure_surfaces_as_node_failure() {
        let env = FlakyEnv::new(Arc::new(LocalEnvironment::new(1)), 1.0, 1);
        let err = env
            .submit(Job::new(noop(), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::NodeFailure { .. }));
    }

    #[test]
    fn plan_grammar_round_trips() {
        let plan = FaultPlan::parse("drop=0.2;hang=0.01,delay=0.1:60;crash=40+8").unwrap();
        assert_eq!(plan.drop_rate, 0.2);
        assert_eq!(plan.hang_rate, 0.01);
        assert_eq!(plan.straggler_rate, 0.1);
        assert_eq!(plan.straggler_delay_s, 60.0);
        assert_eq!(plan.crash_windows, vec![CrashWindow { start: 40, len: 8 }]);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);

        for bad in [
            "x",
            "drop",
            "drop=nope",
            "drop=1.5",
            "delay=0.1",
            "delay=0.1:x",
            "crash=40",
            "crash=a+b",
            "warp=0.1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn same_plan_and_seed_reproduce_the_same_fault_stream() {
        let mk = || {
            FaultyEnv::new(
                Arc::new(LocalEnvironment::new(1)),
                FaultPlan::new().drops(0.3).stragglers(0.2, 10.0),
                99,
            )
        };
        let (a, b) = (mk(), mk());
        for _ in 0..100 {
            let ra = a.submit(Job::new(noop(), Context::new())).wait();
            let rb = b.submit(Job::new(noop(), Context::new())).wait();
            assert_eq!(ra.is_ok(), rb.is_ok());
        }
        assert_eq!(a.injected(), b.injected());
    }

    #[test]
    fn crash_window_fails_exact_submissions_then_recovers() {
        let env = FaultyEnv::new(
            Arc::new(LocalEnvironment::new(1)),
            FaultPlan::new().crash_window(2, 3),
            7,
        );
        let results: Vec<bool> = (0..8)
            .map(|_| env.submit(Job::new(noop(), Context::new())).wait().is_ok())
            .collect();
        assert_eq!(
            results,
            vec![true, true, false, false, false, true, true, true]
        );
        assert_eq!(env.injected().crash_failures, 3);
    }

    #[test]
    fn hung_job_never_completes_but_ledger_reconciles() {
        // satellite: submitted = completed + failed + in_flight under a
        // mixed plan, with hangs held open as in-flight
        let env = FaultyEnv::new(
            Arc::new(LocalEnvironment::new(2)),
            FaultPlan::new().drops(0.2).hangs(0.15).crash_window(0, 2),
            13,
        );
        let n = 60u64;
        let handles: Vec<JobHandle> = (0..n)
            .map(|_| env.submit(Job::new(noop(), Context::new())))
            .collect();
        // settle every non-hung handle; hung ones stay None forever
        let mut completed = 0u64;
        let mut failed = 0u64;
        let mut pending = 0u64;
        for h in &handles {
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
            loop {
                match h.try_wait() {
                    Some(Ok(_)) => {
                        completed += 1;
                        break;
                    }
                    Some(Err(_)) => {
                        failed += 1;
                        break;
                    }
                    None if std::time::Instant::now() > deadline => {
                        pending += 1;
                        break;
                    }
                    None => std::thread::sleep(std::time::Duration::from_millis(1)),
                }
            }
        }
        let inj = env.injected();
        assert_eq!(inj.crash_failures, 2);
        assert!(inj.hangs > 0, "expected some hangs at 15% of {n}");
        assert_eq!(pending, inj.hangs, "every pending handle is a hang");
        let s = env.stats();
        assert_eq!(s.submitted, n);
        assert_eq!(s.completed, completed);
        assert_eq!(s.failed_jobs, failed);
        assert_eq!(
            s.completed + s.failed_jobs + s.in_flight(),
            s.submitted,
            "ledger must reconcile under injection"
        );
        assert_eq!(s.in_flight(), inj.hangs);
        assert_eq!(s.injected_faults, inj.total());
    }

    #[test]
    fn stragglers_stretch_virtual_time_only() {
        let env = FaultyEnv::new(
            Arc::new(LocalEnvironment::new(1)),
            FaultPlan::new().stragglers(1.0, 40.0),
            3,
        );
        let (_, report) = env.submit(Job::new(noop(), Context::new())).wait().unwrap();
        // delay is 40 × [0.5, 1.5) virtual seconds on top of a ~0-cost task
        assert!(
            (20.0..60.0 + 1.0).contains(&report.exec_s),
            "stretched exec_s = {}",
            report.exec_s
        );
        assert!(report.virtual_end >= report.virtual_start + 20.0);
        assert_eq!(env.injected().stragglers, 1);
    }
}
