//! Run journal: a dependency-free JSONL checkpoint stream that makes long
//! optimisations killable and resumable (`--resume <journal>`).
//!
//! Every record is one JSON object per line with a `"kind"` tag. The
//! records a calibration run writes:
//!
//! ```text
//! {"kind":"run_start","run":"calibrate","seed":42,"mu":8,"lambda":8}
//! {"kind":"generation","generation":0,"evaluations":8,"clock":412.7,"rng":["718...","92...","33...","105..."],"population":[{"genome":[3.1,88.0],"objectives":[12.0,4.5,9.1],"evals":1},...]}
//! {"kind":"generation","generation":1,"evaluations":16,...}
//! {"kind":"env_stats","env":"broker","submitted":16,"completed":16,"failed_attempts":2,"resubmissions":2,"failed_jobs":0}
//! {"kind":"run_end","evaluations":16,"clock":2201.4}
//! ```
//!
//! A `generation` record captures everything the generational driver
//! needs to continue: the selected population (genomes, running-average
//! objectives, per-individual evaluation counts), the virtual clock, the
//! global evaluation counter, and the raw RNG state (serialised as
//! strings — u64 does not fit in a JSON double). Because the objective
//! values round-trip exactly through the shortest-representation float
//! writer, a killed run resumed from its journal reaches a final Pareto
//! front bit-identical to an uninterrupted run with the same seed.
//!
//! Island runs append `island` progress records and periodic `archive`
//! snapshots instead; resuming seeds the archive and continues the
//! remaining evaluation budget.
//!
//! Explore sweeps write `sample_block` checkpoints, and — when running
//! with `--degraded-ok` — `degraded_rows` records naming the exact design
//! rows whose retry budget was exhausted (their objectives are emitted as
//! NaN/null). On `--resume` the two record kinds replay in write order
//! (see [`sweep_events`]): a later `sample_block` covering a previously
//! degraded row supersedes it.
//!
//! # Segments and compaction
//!
//! A per-run journal can roll: `exp.jsonl` (segment 0) is continued by
//! `exp.1.jsonl`, `exp.2.jsonl`, ... once a segment passes `roll_every`
//! appends ([`Journal::create_rolling`] / [`Journal::append_to_rolling`]),
//! so one file never grows without bound under a long campaign.
//! [`Journal::load_segmented`] folds every segment in ascending order —
//! and reads a legacy single-file journal unchanged, since that is just
//! segment 0. On resume, [`Journal::compact_segments`] rewrites a
//! multi-segment history as one snapshot segment (see
//! [`compact_records`]): superseded `generation`/`archive` checkpoints
//! drop, sweep events fold last-wins into their final per-row state. The
//! same snapshot-then-delete step `molers serve` applies to its
//! meta-journal (`serve::registry`).

use std::collections::BTreeMap;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::environment::EnvStats;
use crate::error::{Error, Result};
use crate::evolution::genome::Individual;
use crate::evolution::popmatrix::PopMatrix;
use crate::util::json::{parse, Json};
use crate::util::Rng;

/// Size of the writer's assembly buffer: big enough that even a large
/// population checkpoint drains as a few MiB-sized writes rather than one
/// syscall per `write_fmt` fragment (a number, a comma...), small enough
/// to be irrelevant beside the checkpoint data itself.
const WRITE_BUFFER_BYTES: usize = 1 << 20;

/// Default appends per journal segment before a roll (the same threshold
/// the serve meta-journal uses).
pub const DEFAULT_ROLL_EVERY: usize = 4096;

/// When an appended record becomes *durable* — the power-loss contract
/// of a [`Journal`], orthogonal to the flush-per-record process-crash
/// contract (every policy survives a `kill -9`; they differ on what a
/// host power cut can take back).
///
/// `molers serve` journals its meta-journal with [`Durability::Always`]
/// (an acknowledged submission survives power loss); per-experiment
/// checkpoint journals default to [`Durability::Os`] (a lost checkpoint
/// merely re-evaluates rows) unless `--durability` says otherwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Durability {
    /// `fdatasync` after every record: the append call returns only once
    /// the record is on stable storage.
    Always,
    /// `fdatasync` every N records (and on [`Journal::sync`]): bounded
    /// power-loss window, amortised sync cost.
    Batch(usize),
    /// Flush into the OS page cache only: survives process death, not
    /// power loss. The pre-durability behaviour.
    Os,
}

impl Durability {
    /// Parse a `--durability` value: `always`, `os`, `batch` (default
    /// window 64) or `batch:N`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "always" => Some(Durability::Always),
            "os" => Some(Durability::Os),
            "batch" => Some(Durability::Batch(64)),
            _ => {
                let n: usize = s.strip_prefix("batch:")?.parse().ok()?;
                if n == 0 {
                    None
                } else {
                    Some(Durability::Batch(n))
                }
            }
        }
    }
}

impl std::fmt::Display for Durability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Durability::Always => write!(f, "always"),
            Durability::Batch(n) => write!(f, "batch:{n}"),
            Durability::Os => write!(f, "os"),
        }
    }
}

/// Write `contents` to `path` atomically and durably: a temp file in the
/// same directory is written, `fdatasync`'d, renamed over `path`, and
/// the directory entry itself is fsync'd — a reader (or a restart after
/// power loss) sees either the old file or the complete new one, never a
/// partial write.
pub fn atomic_write(path: impl AsRef<Path>, contents: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let dir = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("atomic");
    let tmp = dir.join(format!(".{name}.tmp.{}", std::process::id()));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents)?;
        f.sync_data()?;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    fsync_dir(&dir);
    Ok(())
}

/// Path of journal segment `n` for base path `base`: segment 0 IS the
/// base (`exp.jsonl`), segment N ≥ 1 is a numbered sibling
/// (`exp.N.jsonl` — the number sits before the extension so shell globs
/// like `exp*.jsonl` still match).
pub fn seg_path(base: &Path, n: u64) -> PathBuf {
    if n == 0 {
        return base.to_path_buf();
    }
    let name = match (
        base.file_stem().and_then(|s| s.to_str()),
        base.extension().and_then(|s| s.to_str()),
    ) {
        (Some(stem), Some(ext)) => format!("{stem}.{n}.{ext}"),
        _ => format!(
            "{}.{n}",
            base.file_name().and_then(|s| s.to_str()).unwrap_or("journal")
        ),
    };
    base.with_file_name(name)
}

/// Every on-disk segment of the journal at `base`, ascending by segment
/// number. A plain single-file journal is one segment (number 0); a
/// missing journal is the empty list.
pub fn journal_segments(base: &Path) -> Vec<(u64, PathBuf)> {
    let mut segs = Vec::new();
    if base.is_file() {
        segs.push((0u64, base.to_path_buf()));
    }
    if let (Some(stem), Some(ext)) = (
        base.file_stem().and_then(|s| s.to_str()),
        base.extension().and_then(|s| s.to_str()),
    ) {
        let dir = match base.parent() {
            Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
            _ => PathBuf::from("."),
        };
        let prefix = format!("{stem}.");
        let suffix = format!(".{ext}");
        if let Ok(entries) = std::fs::read_dir(&dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(name) = name.to_str() else { continue };
                if let Some(mid) = name
                    .strip_prefix(&prefix)
                    .and_then(|s| s.strip_suffix(&suffix))
                {
                    if let Ok(n) = mid.parse::<u64>() {
                        if n > 0 {
                            segs.push((n, entry.path()));
                        }
                    }
                }
            }
        }
    }
    segs.sort_by_key(|(n, _)| *n);
    segs
}

/// Best-effort directory fsync — makes a just-completed rename/create/
/// unlink in `dir` durable. Failure is swallowed: some filesystems
/// refuse to open directories, and the data-loss window it leaves is the
/// pre-durability status quo, not a new error path.
pub fn fsync_dir(dir: impl AsRef<Path>) {
    if let Ok(d) = std::fs::File::open(dir.as_ref()) {
        let _ = d.sync_all();
    }
}

/// Best-effort file fsync by path (used to pin an already-written result
/// file to stable storage before its terminal state is journaled).
pub fn fsync_file(path: impl AsRef<Path>) {
    if let Ok(f) = std::fs::File::open(path.as_ref()) {
        let _ = f.sync_data();
    }
}

/// Append-only JSONL checkpoint writer. Clone-free and lock-cheap: one
/// record per line assembled in a [`BufWriter`] (see
/// [`WRITE_BUFFER_BYTES`]), explicitly flushed once per checkpoint —
/// unbuffered, a 200k-population generation record's formatting issued a
/// write syscall per fragment; buffered it drains in buffer-sized
/// chunks. A `kill -9` still loses at most the line being written (the
/// loader tolerates a torn final line, and [`Journal::append_to`]
/// repairs it before continuing).
pub struct Journal {
    /// Segment-0 (base) path — the journal's identity even when appends
    /// currently land in a higher-numbered segment.
    path: PathBuf,
    durability: Durability,
    /// Appends per segment before a roll; 0 = never roll (the plain
    /// single-file constructors).
    roll_every: usize,
    file: Mutex<Writer>,
}

/// The locked writer state: the assembly buffer plus the count of
/// records flushed to the OS but not yet fsync'd (for
/// [`Durability::Batch`]) and the roll bookkeeping.
struct Writer {
    buf: BufWriter<std::fs::File>,
    unsynced: usize,
    /// Records appended into the current segment.
    appended: usize,
    /// Segment number the appends currently land in.
    seg_no: u64,
}

impl Journal {
    /// Start a fresh journal (truncates an existing file) with the
    /// default [`Durability::Os`] policy.
    pub fn create(path: impl AsRef<Path>) -> Result<Self> {
        Self::create_with(path, Durability::Os)
    }

    /// Start a fresh journal with an explicit [`Durability`] policy.
    pub fn create_with(path: impl AsRef<Path>, durability: Durability) -> Result<Self> {
        Self::create_tuned(path, durability, 0)
    }

    /// Start a fresh *rolling* journal: the file rolls to numbered
    /// segments (see [`seg_path`]) every `roll_every` appends. Stale
    /// segments of a previous journal with the same base name are
    /// deleted first — they would otherwise replay into this run.
    pub fn create_rolling(
        path: impl AsRef<Path>,
        durability: Durability,
        roll_every: usize,
    ) -> Result<Self> {
        for (n, seg) in journal_segments(path.as_ref()) {
            if n > 0 {
                let _ = std::fs::remove_file(seg);
            }
        }
        Self::create_tuned(path, durability, roll_every)
    }

    fn create_tuned(
        path: impl AsRef<Path>,
        durability: Durability,
        roll_every: usize,
    ) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = std::fs::File::create(&path)?;
        Ok(Journal {
            path,
            durability,
            roll_every,
            file: Mutex::new(Writer {
                buf: BufWriter::with_capacity(WRITE_BUFFER_BYTES, file),
                unsynced: 0,
                appended: 0,
                seg_no: 0,
            }),
        })
    }

    /// Continue an existing journal (used by `--resume`) with the
    /// default [`Durability::Os`] policy.
    ///
    /// A process killed mid-write leaves an unterminated final line;
    /// appending onto it would weld the fragment to the next record and
    /// corrupt the file *mid-stream* (which [`Journal::load`] treats as
    /// fatal). So the torn tail is truncated first — the same fragment
    /// `load` already ignores.
    pub fn append_to(path: impl AsRef<Path>) -> Result<Self> {
        Self::append_to_with(path, Durability::Os)
    }

    /// Continue an existing journal with an explicit [`Durability`]
    /// policy (torn-tail repair as in [`Journal::append_to`]).
    pub fn append_to_with(path: impl AsRef<Path>, durability: Durability) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = open_append_repaired(&path)?;
        Ok(Journal {
            path,
            durability,
            roll_every: 0,
            file: Mutex::new(Writer {
                buf: BufWriter::with_capacity(WRITE_BUFFER_BYTES, file),
                unsynced: 0,
                appended: 0,
                seg_no: 0,
            }),
        })
    }

    /// Continue a possibly-segmented journal: appends land in the
    /// highest existing segment (torn-tail repaired) and roll onward
    /// from there every `roll_every` records. A legacy single-file
    /// journal is just segment 0, so it is continued — and starts
    /// rolling — transparently.
    pub fn append_to_rolling(
        path: impl AsRef<Path>,
        durability: Durability,
        roll_every: usize,
    ) -> Result<Self> {
        let base = path.as_ref().to_path_buf();
        let (seg_no, seg) = journal_segments(&base)
            .pop()
            .unwrap_or((0, base.clone()));
        let file = open_append_repaired(&seg)?;
        Ok(Journal {
            path: base,
            durability,
            roll_every,
            file: Mutex::new(Writer {
                buf: BufWriter::with_capacity(WRITE_BUFFER_BYTES, file),
                unsynced: 0,
                appended: 0,
                seg_no,
            }),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn durability(&self) -> Durability {
        self.durability
    }

    /// Append one record as a line, flush it to the OS, and make it
    /// durable per the journal's [`Durability`] policy: the record is
    /// assembled in the writer's buffer (buffer-sized writes, not one
    /// syscall per formatted fragment), flushed, and — under `always`,
    /// or when a `batch` window fills — `fdatasync`'d before this call
    /// returns, so an acknowledgement sent after `append` can never
    /// refer to a record a power cut takes back.
    pub fn append(&self, record: &Json) -> Result<()> {
        let mut w = self.file.lock().unwrap();
        writeln!(w.buf, "{record}")?;
        w.buf.flush()?;
        match self.durability {
            Durability::Always => w.buf.get_ref().sync_data()?,
            Durability::Batch(n) => {
                w.unsynced += 1;
                if w.unsynced >= n {
                    w.buf.get_ref().sync_data()?;
                    w.unsynced = 0;
                }
            }
            Durability::Os => {}
        }
        w.appended += 1;
        if self.roll_every > 0 && w.appended >= self.roll_every {
            // seal this segment (its records must be durable before the
            // next segment claims the tail of the stream) and roll
            w.buf.get_ref().sync_data()?;
            let next = w.seg_no + 1;
            let file = std::fs::File::create(seg_path(&self.path, next))?;
            match self.path.parent() {
                Some(p) if !p.as_os_str().is_empty() => fsync_dir(p),
                _ => fsync_dir("."),
            }
            w.buf = BufWriter::with_capacity(WRITE_BUFFER_BYTES, file);
            w.seg_no = next;
            w.appended = 0;
            w.unsynced = 0;
        }
        Ok(())
    }

    /// Flush and `fdatasync` unconditionally — a checkpoint boundary
    /// under [`Durability::Batch`]/[`Durability::Os`], a no-op cost on
    /// top of [`Durability::Always`].
    pub fn sync(&self) -> Result<()> {
        let mut w = self.file.lock().unwrap();
        w.buf.flush()?;
        w.buf.get_ref().sync_data()?;
        w.unsynced = 0;
        Ok(())
    }

    /// Parse a journal back into records. A torn final line (the process
    /// died mid-write) is dropped; corruption anywhere else is an error.
    ///
    /// A power cut can leave *arbitrary* bytes in the tail (zeros,
    /// garbage), so the file is decoded lossily: invalid UTF-8 becomes
    /// replacement characters, which fail JSON parsing — dropped when
    /// they sit on the final line, a loud error anywhere earlier.
    pub fn load(path: impl AsRef<Path>) -> Result<Vec<Json>> {
        let bytes = std::fs::read(path.as_ref())?;
        let text = String::from_utf8_lossy(&bytes);
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        let mut records = Vec::with_capacity(lines.len());
        for (i, line) in lines.iter().enumerate() {
            match parse(line) {
                Ok(rec) => records.push(rec),
                Err(_) if i + 1 == lines.len() => break, // torn tail
                Err(e) => {
                    return Err(Error::EnvironmentError {
                        environment: "journal".into(),
                        message: format!("corrupt journal line {}: {e}", i + 1),
                    })
                }
            }
        }
        Ok(records)
    }

    /// Load a possibly-segmented journal: every segment's records folded
    /// in ascending segment order. A legacy single-file journal loads
    /// identically to [`Journal::load`]; a missing one errors the same
    /// way.
    pub fn load_segmented(base: impl AsRef<Path>) -> Result<Vec<Json>> {
        let base = base.as_ref();
        let segs = journal_segments(base);
        if segs.is_empty() {
            return Self::load(base);
        }
        let mut records = Vec::new();
        for (_, seg) in &segs {
            records.extend(Self::load(seg)?);
        }
        Ok(records)
    }

    /// Load a possibly-segmented journal and, when more than one segment
    /// exists, rewrite the history as a single compacted snapshot
    /// segment (see [`compact_records`]) — atomically written as segment
    /// max+1, then the old segments are deleted. Returns the records the
    /// surviving layout replays to. A single-file journal is returned
    /// as-is: the legacy layout keeps working untouched.
    pub fn compact_segments(base: impl AsRef<Path>) -> Result<Vec<Json>> {
        let base = base.as_ref();
        let segs = journal_segments(base);
        if segs.is_empty() {
            return Self::load(base);
        }
        let mut records = Vec::new();
        for (_, seg) in &segs {
            records.extend(Self::load(seg)?);
        }
        if segs.len() <= 1 {
            return Ok(records);
        }
        let compacted = compact_records(&records);
        let mut body = String::new();
        for r in &compacted {
            body.push_str(&r.to_string());
            body.push('\n');
        }
        let snap = seg_path(base, segs.last().unwrap().0 + 1);
        atomic_write(&snap, body.as_bytes())?;
        for (_, old) in &segs {
            let _ = std::fs::remove_file(old);
        }
        match base.parent() {
            Some(p) if !p.as_os_str().is_empty() => fsync_dir(p),
            _ => fsync_dir("."),
        }
        Ok(compacted)
    }
}

/// Torn-tail repair + open-for-append of one journal segment (see
/// [`Journal::append_to`] for the contract).
fn open_append_repaired(path: &Path) -> Result<std::fs::File> {
    // bytes, not read_to_string: a power cut can leave a non-UTF-8
    // tail, which must not silently skip the repair
    if let Ok(bytes) = std::fs::read(path) {
        if !bytes.is_empty() && bytes.last() != Some(&b'\n') {
            let keep = bytes
                .iter()
                .rposition(|&b| b == b'\n')
                .map(|i| i + 1)
                .unwrap_or(0);
            eprintln!(
                "journal: repaired torn tail of `{}`: dropped 1 partial \
                 record ({} bytes from byte offset {keep})",
                path.display(),
                bytes.len() - keep,
            );
            let f = std::fs::OpenOptions::new().write(true).open(path)?;
            f.set_len(keep as u64)?;
            f.sync_data()?;
        }
    }
    Ok(std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?)
}

/// Fold a journal's records to the minimal set that replays to the same
/// state — the startup-compaction step of [`Journal::compact_segments`]:
///
/// * `generation` / `archive` / `island` — only the last of each kind
///   matters to a resume; earlier checkpoints drop.
/// * `sample_block` / `degraded_rows` — replayed last-wins into the
///   final per-row state, then re-emitted as one `sample_block` per
///   contiguous completed run plus one `degraded_rows` record (clocks
///   collapse to the stream's maximum, which is all a resume reads).
/// * everything else (`run_start`, `env_stats`, `run_end`, unknown
///   kinds) — kept verbatim in order, so resume validation against
///   `run_start` fields is unaffected.
pub fn compact_records(records: &[Json]) -> Vec<Json> {
    let last_of = |k: &str| records.iter().rposition(|r| kind(r) == Some(k));
    let last_generation = last_of("generation");
    let last_archive = last_of("archive");
    let last_island = last_of("island");
    let mut out = Vec::new();
    let mut sweep_emitted = false;
    for (i, r) in records.iter().enumerate() {
        match kind(r) {
            Some("generation") if Some(i) != last_generation => {}
            Some("archive") if Some(i) != last_archive => {}
            Some("island") if Some(i) != last_island => {}
            Some("sample_block") | Some("degraded_rows") => {
                if !sweep_emitted {
                    sweep_emitted = true;
                    out.extend(fold_sweep_state(records));
                }
            }
            _ => out.push(r.clone()),
        }
    }
    out
}

/// The final per-row state of a sweep-event stream, re-emitted as
/// records (see [`compact_records`]).
fn fold_sweep_state(records: &[Json]) -> Vec<Json> {
    enum Row {
        Done(Vec<f64>),
        Degraded,
    }
    let mut state: BTreeMap<usize, Row> = BTreeMap::new();
    let mut clock = 0.0f64;
    for ev in sweep_events(records) {
        match ev {
            SweepEvent::Block(b) => {
                for (k, objs) in b.objectives.into_iter().enumerate() {
                    if objs.is_empty() {
                        continue; // nothing restorable; the row re-evaluates
                    }
                    state.insert(b.first_row + k, Row::Done(objs));
                }
                clock = clock.max(b.clock);
            }
            SweepEvent::Degraded(d) => {
                for r in d.rows {
                    state.insert(r, Row::Degraded);
                }
                clock = clock.max(d.clock);
            }
        }
    }
    let mut out = Vec::new();
    let mut degraded: Vec<usize> = Vec::new();
    // contiguous completed runs of equal objective width become one
    // block each; the BTreeMap iterates rows ascending
    let mut run_start: Option<usize> = None;
    let mut run_next = 0usize;
    let mut n_obj = 0usize;
    let mut flat: Vec<f64> = Vec::new();
    let mut flush =
        |start: &mut Option<usize>, flat: &mut Vec<f64>, n_obj: usize, out: &mut Vec<Json>| {
            if let Some(s) = start.take() {
                out.push(sample_block_record(s, n_obj, flat, clock));
                flat.clear();
            }
        };
    for (row, st) in &state {
        match st {
            Row::Degraded => {
                flush(&mut run_start, &mut flat, n_obj, &mut out);
                degraded.push(*row);
            }
            Row::Done(objs) => {
                if run_start.is_some() && (*row != run_next || objs.len() != n_obj) {
                    flush(&mut run_start, &mut flat, n_obj, &mut out);
                }
                if run_start.is_none() {
                    run_start = Some(*row);
                    n_obj = objs.len();
                }
                flat.extend_from_slice(objs);
                run_next = row + 1;
            }
        }
    }
    flush(&mut run_start, &mut flat, n_obj, &mut out);
    if !degraded.is_empty() {
        out.push(degraded_rows_record(&degraded, clock, "compacted"));
    }
    out
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn f64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn individual_json(ind: &Individual) -> Json {
    obj(vec![
        ("genome", f64_arr(&ind.genome)),
        ("objectives", f64_arr(&ind.objectives)),
        ("evals", Json::Num(f64::from(ind.evaluations))),
    ])
}

fn parse_f64_arr(j: &Json) -> Option<Vec<f64>> {
    // strict: any non-numeric element rejects the record — silently
    // dropping one would resume with a truncated genome/objective vector
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

fn parse_individual(j: &Json) -> Option<Individual> {
    Some(Individual {
        genome: parse_f64_arr(j.get("genome")?)?,
        objectives: parse_f64_arr(j.get("objectives")?)?,
        evaluations: j.get("evals")?.as_f64()? as u32,
    })
}

fn population_json(population: &[Individual]) -> Json {
    Json::Arr(population.iter().map(individual_json).collect())
}

/// Serialise straight from matrix rows — no intermediate [`Individual`]
/// per row. Produces exactly the same JSON as [`population_json`] on the
/// equivalent AoS population, so matrix- and AoS-written journals are
/// interchangeable (and `parse_population` reads both).
fn population_json_matrix(population: &PopMatrix) -> Json {
    Json::Arr(
        (0..population.len())
            .map(|i| {
                obj(vec![
                    ("genome", f64_arr(population.genome(i))),
                    ("objectives", f64_arr(population.objectives_row(i))),
                    ("evals", Json::Num(f64::from(population.evals(i)))),
                ])
            })
            .collect(),
    )
}

fn parse_population(j: &Json) -> Option<Vec<Individual>> {
    j.as_arr()?.iter().map(parse_individual).collect()
}

/// `run_start` record.
pub fn run_start(run: &str, seed: u64, extra: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![
        ("kind", Json::Str("run_start".into())),
        ("run", Json::Str(run.into())),
        ("seed", Json::Num(seed as f64)),
    ];
    pairs.extend(extra);
    obj(pairs)
}

/// Shared field list of a `generation` record — the single place both the
/// AoS and the matrix writers assemble it, so the two journal encodings
/// cannot drift apart field-wise.
fn generation_record_with(
    generation: u32,
    evaluations: u64,
    clock: f64,
    rng: &Rng,
    population: Json,
) -> Json {
    obj(vec![
        ("kind", Json::Str("generation".into())),
        ("generation", Json::Num(f64::from(generation))),
        ("evaluations", Json::Num(evaluations as f64)),
        ("clock", Json::Num(clock)),
        (
            "rng",
            Json::Arr(
                rng.state()
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
            ),
        ),
        ("population", population),
    ])
}

/// `generation` checkpoint record (generational driver, AoS edge).
pub fn generation_record(
    generation: u32,
    evaluations: u64,
    clock: f64,
    rng: &Rng,
    population: &[Individual],
) -> Json {
    generation_record_with(
        generation,
        evaluations,
        clock,
        rng,
        population_json(population),
    )
}

/// `generation` checkpoint record serialised straight from matrix rows
/// (the columnar engines' path — byte-identical to [`generation_record`]
/// on the equivalent AoS population).
pub fn generation_record_matrix(
    generation: u32,
    evaluations: u64,
    clock: f64,
    rng: &Rng,
    population: &PopMatrix,
) -> Json {
    generation_record_with(
        generation,
        evaluations,
        clock,
        rng,
        population_json_matrix(population),
    )
}

/// Shared field list of an `archive` record (see [`generation_record_with`]).
fn archive_record_with(evaluations: u64, population: Json) -> Json {
    obj(vec![
        ("kind", Json::Str("archive".into())),
        ("evaluations", Json::Num(evaluations as f64)),
        ("population", population),
    ])
}

/// `archive` snapshot record from matrix rows (island driver).
pub fn archive_record_matrix(evaluations: u64, population: &PopMatrix) -> Json {
    archive_record_with(evaluations, population_json_matrix(population))
}

/// `island` progress record (island driver).
pub fn island_record(islands_completed: u64, evaluations: u64, clock: f64) -> Json {
    obj(vec![
        ("kind", Json::Str("island".into())),
        ("islands_completed", Json::Num(islands_completed as f64)),
        ("evaluations", Json::Num(evaluations as f64)),
        ("clock", Json::Num(clock)),
    ])
}

/// `archive` snapshot record (island driver, AoS edge).
pub fn archive_record(evaluations: u64, population: &[Individual]) -> Json {
    archive_record_with(evaluations, population_json(population))
}

/// `sample_block` checkpoint of an explore sweep (§Exploration): the
/// evaluated objective rows of design rows
/// `first_row .. first_row + rows`. The design itself is never journaled —
/// it regenerates deterministically from the sweep's sampling + seed — so
/// a block is just its position and the objectives:
///
/// ```text
/// {"kind":"sample_block","first_row":512,"rows":2,"clock":88.5,"objectives":[[0.5,3.1],[0.25,2.0]]}
/// ```
///
/// Objectives round-trip exactly (shortest-representation floats), which
/// is what makes a resumed sweep's result file byte-identical to an
/// uninterrupted run's.
pub fn sample_block_record(
    first_row: usize,
    n_obj: usize,
    objectives: &[f64],
    clock: f64,
) -> Json {
    debug_assert!(n_obj > 0 && objectives.len() % n_obj == 0);
    obj(vec![
        ("kind", Json::Str("sample_block".into())),
        ("first_row", Json::Num(first_row as f64)),
        ("rows", Json::Num((objectives.len() / n_obj.max(1)) as f64)),
        ("clock", Json::Num(clock)),
        (
            "objectives",
            Json::Arr(objectives.chunks(n_obj.max(1)).map(f64_arr).collect()),
        ),
    ])
}

/// One parsed sweep checkpoint block.
#[derive(Debug, Clone, PartialEq)]
pub struct SampleBlock {
    pub first_row: usize,
    /// One objective vector per design row of the block.
    pub objectives: Vec<Vec<f64>>,
    pub clock: f64,
}

fn parse_sample_block(rec: &Json) -> Option<SampleBlock> {
    Some(SampleBlock {
        first_row: rec.get("first_row")?.as_f64()? as usize,
        objectives: rec
            .get("objectives")?
            .as_arr()?
            .iter()
            .map(parse_f64_arr)
            .collect::<Option<Vec<_>>>()?,
        clock: rec.get("clock")?.as_f64()?,
    })
}

/// Every well-formed `sample_block` in a sweep journal, in write order. A
/// malformed block is dropped rather than fatal: the sweep simply
/// re-evaluates those rows (deterministic per-row seeds make the redo
/// value-identical).
pub fn sample_blocks(records: &[Json]) -> Vec<SampleBlock> {
    records
        .iter()
        .filter(|r| kind(r) == Some("sample_block"))
        .filter_map(parse_sample_block)
        .collect()
}

/// `env_stats` record.
pub fn env_stats_record(env: &str, s: &EnvStats) -> Json {
    obj(vec![
        ("kind", Json::Str("env_stats".into())),
        ("env", Json::Str(env.into())),
        ("submitted", Json::Num(s.submitted as f64)),
        ("completed", Json::Num(s.completed as f64)),
        ("failed_attempts", Json::Num(s.failed_attempts as f64)),
        ("resubmissions", Json::Num(s.resubmissions as f64)),
        ("failed_jobs", Json::Num(s.failed_jobs as f64)),
        ("timed_out_attempts", Json::Num(s.timed_out_attempts as f64)),
        ("injected_faults", Json::Num(s.injected_faults as f64)),
        ("virtual_makespan", Json::Num(s.virtual_makespan)),
    ])
}

/// `degraded_rows` record: the exact design rows whose retry budget was
/// exhausted under `--degraded-ok`. Their objectives are emitted as
/// NaN/null in the result file; on `--resume` they restore as done (NaN)
/// unless `--retry-degraded` re-opens them.
///
/// ```text
/// {"kind":"degraded_rows","rows":[512,513],"clock":88.5,"error":"..."}
/// ```
pub fn degraded_rows_record(rows: &[usize], clock: f64, error: &str) -> Json {
    obj(vec![
        ("kind", Json::Str("degraded_rows".into())),
        (
            "rows",
            Json::Arr(rows.iter().map(|&r| Json::Num(r as f64)).collect()),
        ),
        ("clock", Json::Num(clock)),
        ("error", Json::Str(error.into())),
    ])
}

/// One parsed `degraded_rows` record.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradedRows {
    pub rows: Vec<usize>,
    pub clock: f64,
}

fn parse_degraded_rows(rec: &Json) -> Option<DegradedRows> {
    Some(DegradedRows {
        rows: rec
            .get("rows")?
            .as_arr()?
            .iter()
            .map(|r| r.as_f64().map(|v| v as usize))
            .collect::<Option<Vec<_>>>()?,
        clock: rec.get("clock")?.as_f64()?,
    })
}

/// Every well-formed `degraded_rows` record, in write order.
pub fn degraded_rows(records: &[Json]) -> Vec<DegradedRows> {
    records
        .iter()
        .filter(|r| kind(r) == Some("degraded_rows"))
        .filter_map(parse_degraded_rows)
        .collect()
}

/// One replayable event of a sweep journal, in write order. Order
/// matters: a degraded row set written in one run can be superseded by a
/// `sample_block` from a later `--retry-degraded` resume, so the restorer
/// must apply events last-wins, not set-union.
#[derive(Debug, Clone, PartialEq)]
pub enum SweepEvent {
    Block(SampleBlock),
    Degraded(DegradedRows),
}

/// Every well-formed `sample_block` / `degraded_rows` record of a sweep
/// journal as one ordered event stream (malformed records are dropped —
/// the sweep just re-evaluates those rows).
pub fn sweep_events(records: &[Json]) -> Vec<SweepEvent> {
    records
        .iter()
        .filter_map(|r| match kind(r) {
            Some("sample_block") => parse_sample_block(r).map(SweepEvent::Block),
            Some("degraded_rows") => {
                parse_degraded_rows(r).map(SweepEvent::Degraded)
            }
            _ => None,
        })
        .collect()
}

/// `run_end` record.
pub fn run_end(evaluations: u64, clock: f64) -> Json {
    obj(vec![
        ("kind", Json::Str("run_end".into())),
        ("evaluations", Json::Num(evaluations as f64)),
        ("clock", Json::Num(clock)),
    ])
}

/// Everything the generational driver needs to continue a killed run.
#[derive(Clone)]
pub struct ResumeState {
    /// Last fully checkpointed generation (resume continues at `+ 1`).
    pub generation: u32,
    pub evaluations: u64,
    pub clock: f64,
    pub rng: Rng,
    pub population: Vec<Individual>,
}

fn kind(rec: &Json) -> Option<&str> {
    rec.get("kind").and_then(Json::as_str)
}

/// Extract the latest generation checkpoint from journal records.
pub fn resume_state(records: &[Json]) -> Option<ResumeState> {
    let rec = records
        .iter()
        .rev()
        .find(|r| kind(r) == Some("generation"))?;
    let rng_state: Vec<u64> = rec
        .get("rng")?
        .as_arr()?
        .iter()
        .filter_map(|s| s.as_str()?.parse::<u64>().ok())
        .collect();
    let rng_state: [u64; 4] = rng_state.try_into().ok()?;
    Some(ResumeState {
        generation: rec.get("generation")?.as_f64()? as u32,
        evaluations: rec.get("evaluations")?.as_f64()? as u64,
        clock: rec.get("clock")?.as_f64()?,
        rng: Rng::from_state(rng_state),
        population: parse_population(rec.get("population")?)?,
    })
}

/// Load a journal and extract its latest generation checkpoint.
pub fn load_resume(path: impl AsRef<Path>) -> Result<Option<ResumeState>> {
    Ok(resume_state(&Journal::load(path)?))
}

/// Latest island-archive snapshot: `(population, evaluations_done)`.
pub fn island_resume(records: &[Json]) -> Option<(Vec<Individual>, u64)> {
    let rec = records.iter().rev().find(|r| kind(r) == Some("archive"))?;
    Some((
        parse_population(rec.get("population")?)?,
        rec.get("evaluations")?.as_f64()? as u64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("molers-journal-{}-{name}", std::process::id()))
    }

    fn pop() -> Vec<Individual> {
        // PI and 0.1000000000000001 have long shortest-representations —
        // they exercise the exact float round-trip the resume guarantee
        // rests on
        vec![
            Individual {
                genome: vec![1.25, 0.1000000000000001],
                objectives: vec![3.5, std::f64::consts::PI],
                evaluations: 3,
            },
            Individual::new(vec![0.0, 99.0], vec![1.0, 2.0]),
        ]
    }

    #[test]
    fn generation_checkpoint_round_trips_exactly() {
        let path = tmp("gen");
        let j = Journal::create(&path).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..13 {
            rng.next_u64();
        }
        j.append(&run_start("calibrate", 7, vec![("mu", Json::Num(2.0))]))
            .unwrap();
        j.append(&generation_record(4, 80, 1234.5678901, &rng, &pop()))
            .unwrap();
        let records = Journal::load(&path).unwrap();
        assert_eq!(records.len(), 2);
        let r = resume_state(&records).expect("checkpoint present");
        assert_eq!(r.generation, 4);
        assert_eq!(r.evaluations, 80);
        assert_eq!(r.clock, 1234.5678901);
        assert_eq!(r.population, pop(), "population must round-trip bit-exactly");
        // the resumed rng continues the exact stream
        let mut resumed = r.rng;
        let mut original = rng;
        for _ in 0..50 {
            assert_eq!(resumed.next_u64(), original.next_u64());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn matrix_records_byte_identical_to_aos_records() {
        let population = pop();
        let matrix = PopMatrix::from_individuals(&population, 2, 2).unwrap();
        let mut rng = Rng::new(3);
        rng.next_u64();
        assert_eq!(
            generation_record_matrix(7, 140, 55.5, &rng, &matrix).to_string(),
            generation_record(7, 140, 55.5, &rng, &population).to_string(),
        );
        assert_eq!(
            archive_record_matrix(140, &matrix).to_string(),
            archive_record(140, &population).to_string(),
        );
        // and the matrix-written record resumes to the same population
        let rec = generation_record_matrix(7, 140, 55.5, &rng, &matrix);
        let state = resume_state(&[rec]).unwrap();
        assert_eq!(state.population, population);
    }

    #[test]
    fn latest_checkpoint_wins() {
        let path = tmp("latest");
        let j = Journal::create(&path).unwrap();
        let rng = Rng::new(1);
        j.append(&generation_record(1, 10, 1.0, &rng, &pop())).unwrap();
        j.append(&generation_record(2, 20, 2.0, &rng, &pop())).unwrap();
        let r = resume_state(&Journal::load(&path).unwrap()).unwrap();
        assert_eq!(r.generation, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_line_is_tolerated() {
        let path = tmp("torn");
        let j = Journal::create(&path).unwrap();
        let rng = Rng::new(1);
        j.append(&generation_record(1, 10, 1.0, &rng, &pop())).unwrap();
        // simulate a kill mid-write of the next checkpoint
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"kind\":\"generation\",\"generation\":2,\"evalu").unwrap();
        }
        let records = Journal::load(&path).unwrap();
        let r = resume_state(&records).unwrap();
        assert_eq!(r.generation, 1, "torn checkpoint must be ignored");

        // resuming must repair the torn tail, not weld new records onto
        // it — otherwise the journal is corrupt mid-file forever after
        {
            let j2 = Journal::append_to(&path).unwrap();
            j2.append(&run_end(10, 1.0)).unwrap();
        }
        let records = Journal::load(&path).unwrap();
        assert_eq!(records.len(), 2, "checkpoint + run_end, fragment gone");
        assert_eq!(kind(&records[1]), Some("run_end"));
        assert_eq!(resume_state(&records).unwrap().generation, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_typed_genome_element_rejects_the_checkpoint() {
        let doc = parse(
            "{\"kind\":\"generation\",\"generation\":1,\"evaluations\":2,\
             \"clock\":1.0,\"rng\":[\"1\",\"2\",\"3\",\"4\"],\
             \"population\":[{\"genome\":[0.5,null,0.7],\
             \"objectives\":[1.0],\"evals\":1}]}",
        )
        .unwrap();
        assert!(
            resume_state(&[doc]).is_none(),
            "a type-corrupted genome must not resume as a shorter one"
        );
    }

    #[test]
    fn corrupt_middle_line_is_an_error() {
        let path = tmp("corrupt");
        std::fs::write(&path, "{\"kind\":\"run_start\"}\nnot json\n{\"kind\":\"run_end\",\"evaluations\":0,\"clock\":0}\n").unwrap();
        let err = Journal::load(&path).unwrap_err();
        assert!(
            err.to_string().contains("line 2"),
            "the error must name the corrupt line: {err}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn degraded_rows_round_trip_in_event_order() {
        let path = tmp("degraded");
        let j = Journal::create(&path).unwrap();
        j.append(&sample_block_record(0, 1, &[1.5], 1.0)).unwrap();
        j.append(&degraded_rows_record(&[2, 3], 2.0, "job deadline"))
            .unwrap();
        // a later retry re-completed row 2: order must be preserved so
        // the restorer can apply last-wins
        j.append(&sample_block_record(2, 1, &[2.5], 3.0)).unwrap();
        let records = Journal::load(&path).unwrap();
        let d = degraded_rows(&records);
        assert_eq!(d, vec![DegradedRows { rows: vec![2, 3], clock: 2.0 }]);
        let events = sweep_events(&records);
        assert_eq!(events.len(), 3);
        assert!(matches!(&events[0], SweepEvent::Block(b) if b.first_row == 0));
        assert!(
            matches!(&events[1], SweepEvent::Degraded(d) if d.rows == vec![2, 3])
        );
        assert!(matches!(&events[2], SweepEvent::Block(b) if b.first_row == 2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn island_archive_round_trips() {
        let path = tmp("island");
        let j = Journal::create(&path).unwrap();
        j.append(&island_record(3, 300, 99.0)).unwrap();
        j.append(&archive_record(300, &pop())).unwrap();
        let records = Journal::load(&path).unwrap();
        let (population, evals) = island_resume(&records).unwrap();
        assert_eq!(evals, 300);
        assert_eq!(population, pop());
        assert!(resume_state(&records).is_none(), "no generation records");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sample_block_round_trips_exactly() {
        let path = tmp("sweep");
        let j = Journal::create(&path).unwrap();
        let objs = [0.5, std::f64::consts::PI, 0.1000000000000001, 2.0];
        j.append(&run_start("explore", 9, vec![("n", Json::Num(4.0))]))
            .unwrap();
        j.append(&sample_block_record(6, 2, &objs, 123.456)).unwrap();
        j.append(&sample_block_record(0, 2, &objs[..2], 99.0)).unwrap();
        let records = Journal::load(&path).unwrap();
        let blocks = sample_blocks(&records);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].first_row, 6);
        assert_eq!(blocks[0].clock, 123.456);
        assert_eq!(
            blocks[0].objectives,
            vec![vec![0.5, std::f64::consts::PI], vec![0.1000000000000001, 2.0]],
            "objectives must round-trip bit-exactly"
        );
        assert_eq!(blocks[1].first_row, 0);
        assert_eq!(blocks[1].objectives.len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_finite_objectives_keep_the_journal_loadable() {
        // NaN serialises as null (not bare NaN, which is not JSON): the
        // journal stays loadable, the strict row parser drops just that
        // block, and the sweep re-evaluates those rows on resume
        let path = tmp("nan");
        let j = Journal::create(&path).unwrap();
        j.append(&sample_block_record(0, 2, &[0.5, f64::NAN], 1.0))
            .unwrap();
        j.append(&sample_block_record(2, 2, &[1.0, 2.0], 2.0)).unwrap();
        let records = Journal::load(&path).expect("journal must stay loadable");
        let blocks = sample_blocks(&records);
        assert_eq!(blocks.len(), 1, "NaN block dropped, finite block kept");
        assert_eq!(blocks[0].first_row, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn malformed_sample_block_is_skipped_not_fatal() {
        let good = sample_block_record(0, 1, &[1.5], 1.0);
        let bad = parse(
            "{\"kind\":\"sample_block\",\"first_row\":2,\"rows\":1,\
             \"clock\":1.0,\"objectives\":[[0.5,null]]}",
        )
        .unwrap();
        let blocks = sample_blocks(&[bad, good]);
        assert_eq!(blocks.len(), 1, "type-corrupted block must be dropped");
        assert_eq!(blocks[0].first_row, 0);
    }

    #[test]
    fn durability_parses_and_round_trips() {
        assert_eq!(Durability::parse("always"), Some(Durability::Always));
        assert_eq!(Durability::parse("os"), Some(Durability::Os));
        assert_eq!(Durability::parse("batch"), Some(Durability::Batch(64)));
        assert_eq!(Durability::parse("batch:7"), Some(Durability::Batch(7)));
        for bad in ["", "batch:0", "batch:x", "fsync", "Always"] {
            assert_eq!(Durability::parse(bad), None, "`{bad}` must be rejected");
        }
        for d in [Durability::Always, Durability::Batch(7), Durability::Os] {
            assert_eq!(Durability::parse(&d.to_string()), Some(d));
        }
    }

    #[test]
    fn every_durability_policy_appends_loadable_records() {
        for (tag, d) in [
            ("always", Durability::Always),
            ("batch", Durability::Batch(2)),
            ("os", Durability::Os),
        ] {
            let path = tmp(&format!("dur-{tag}"));
            let j = Journal::create_with(&path, d).unwrap();
            assert_eq!(j.durability(), d);
            for i in 0..5 {
                j.append(&run_end(i, i as f64)).unwrap();
            }
            j.sync().unwrap();
            assert_eq!(Journal::load(&path).unwrap().len(), 5);
            // reopening for append honours the policy too
            let j2 = Journal::append_to_with(&path, d).unwrap();
            j2.append(&run_end(5, 5.0)).unwrap();
            drop(j2);
            assert_eq!(Journal::load(&path).unwrap().len(), 6);
            let _ = std::fs::remove_file(&path);
        }
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "molers-journal-seg-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn rolling_journal_rolls_and_replays_across_segments() {
        let dir = tmp_dir("roll");
        let base = dir.join("exp.jsonl");
        {
            let j = Journal::create_rolling(&base, Durability::Os, 3).unwrap();
            for i in 0..8 {
                j.append(&run_end(i, i as f64)).unwrap();
            }
        }
        assert!(base.is_file());
        assert!(seg_path(&base, 1).is_file(), "first roll segment");
        assert!(seg_path(&base, 2).is_file(), "second roll segment");
        assert_eq!(Journal::load(&base).unwrap().len(), 3, "base holds one window");
        let all = Journal::load_segmented(&base).unwrap();
        assert_eq!(all.len(), 8, "folded replay sees every record");
        for (i, r) in all.iter().enumerate() {
            assert_eq!(r.get("evaluations").unwrap().as_f64().unwrap() as usize, i);
        }
        // appending continues in the highest segment and rolls onward
        {
            let j = Journal::append_to_rolling(&base, Durability::Os, 3).unwrap();
            for i in 8..12 {
                j.append(&run_end(i, i as f64)).unwrap();
            }
        }
        assert_eq!(Journal::load_segmented(&base).unwrap().len(), 12);
        assert!(seg_path(&base, 3).is_file(), "roll continued past reopen");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_segments_folds_sweep_history_last_wins() {
        let dir = tmp_dir("compact");
        let base = dir.join("exp.jsonl");
        {
            let j = Journal::create_rolling(&base, Durability::Os, 2).unwrap();
            j.append(&run_start("explore", 9, vec![("n", Json::Num(6.0))]))
                .unwrap();
            j.append(&sample_block_record(0, 2, &[1.0, 2.0, 3.0, 4.0], 1.0))
                .unwrap();
            j.append(&degraded_rows_record(&[1, 4], 2.0, "deadline")).unwrap();
            // row 1 later re-completed: it must survive compaction as done
            j.append(&sample_block_record(1, 2, &[9.0, 8.0], 3.0)).unwrap();
            j.append(&env_stats_record("local", &EnvStats::default())).unwrap();
        }
        assert!(journal_segments(&base).len() > 1, "history must be segmented");
        let records = Journal::compact_segments(&base).unwrap();
        // the surviving layout is a single snapshot segment
        let segs = journal_segments(&base);
        assert_eq!(segs.len(), 1, "old segments deleted: {segs:?}");
        assert!(segs[0].0 > 0, "snapshot takes a fresh segment number");
        assert_eq!(Journal::load_segmented(&base).unwrap().len(), records.len());
        // replayed state: rows 0..2 done (row 1 with the LATER values),
        // row 4 degraded; run_start/env_stats kept for validation
        assert_eq!(kind(&records[0]), Some("run_start"));
        let events = sweep_events(&records);
        let mut done: Vec<(usize, Vec<f64>)> = Vec::new();
        let mut degraded: Vec<usize> = Vec::new();
        for ev in events {
            match ev {
                SweepEvent::Block(b) => {
                    for (k, o) in b.objectives.into_iter().enumerate() {
                        done.push((b.first_row + k, o));
                    }
                }
                SweepEvent::Degraded(d) => degraded.extend(d.rows),
            }
        }
        done.sort_by_key(|(r, _)| *r);
        assert_eq!(
            done,
            vec![(0, vec![1.0, 2.0]), (1, vec![9.0, 8.0])],
            "last-wins per row"
        );
        assert_eq!(degraded, vec![4]);
        assert!(
            records.iter().any(|r| kind(r) == Some("env_stats")),
            "non-sweep records pass through"
        );
        // the compacted journal continues accepting appends
        let j = Journal::append_to_rolling(&base, Durability::Os, 2).unwrap();
        j.append(&run_end(4, 3.0)).unwrap();
        drop(j);
        assert!(Journal::load_segmented(&base)
            .unwrap()
            .iter()
            .any(|r| kind(r) == Some("run_end")));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_file_journals_load_and_compact_unchanged() {
        let dir = tmp_dir("legacy");
        let base = dir.join("old.jsonl");
        {
            let j = Journal::create(&base).unwrap();
            j.append(&run_start("explore", 1, vec![])).unwrap();
            j.append(&sample_block_record(0, 1, &[1.5], 1.0)).unwrap();
        }
        let before = std::fs::read(&base).unwrap();
        let via_load = Journal::load(&base).unwrap();
        let via_seg = Journal::load_segmented(&base).unwrap();
        assert_eq!(via_load.len(), via_seg.len());
        let compacted = Journal::compact_segments(&base).unwrap();
        assert_eq!(compacted.len(), 2);
        assert_eq!(
            std::fs::read(&base).unwrap(),
            before,
            "a single-file journal must not be rewritten"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn seg_path_numbers_sit_before_the_extension() {
        let base = PathBuf::from("/state/exp-3.jsonl");
        assert_eq!(seg_path(&base, 0), base);
        assert_eq!(seg_path(&base, 2), PathBuf::from("/state/exp-3.2.jsonl"));
        // and a neighbouring journal's segments never alias: exp-31's
        // names don't parse as exp-3 segments
        let dir = tmp_dir("alias");
        let a = dir.join("exp-3.jsonl");
        std::fs::write(&a, "").unwrap();
        std::fs::write(dir.join("exp-31.jsonl"), "").unwrap();
        std::fs::write(dir.join("exp-3.1.jsonl"), "").unwrap();
        let segs = journal_segments(&a);
        assert_eq!(
            segs.iter().map(|(n, _)| *n).collect::<Vec<_>>(),
            vec![0, 1],
            "{segs:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn atomic_write_replaces_content_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!(
            "molers-atomic-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("addr");
        atomic_write(&path, b"127.0.0.1:1\n").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"127.0.0.1:1\n");
        atomic_write(&path, b"127.0.0.1:2\n").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"127.0.0.1:2\n");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files must not survive: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_to_continues_a_file() {
        let path = tmp("append");
        {
            let j = Journal::create(&path).unwrap();
            j.append(&run_start("calibrate", 1, vec![])).unwrap();
        }
        {
            let j = Journal::append_to(&path).unwrap();
            j.append(&run_end(5, 1.0)).unwrap();
        }
        let records = Journal::load(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(kind(&records[1]), Some("run_end"));
        let _ = std::fs::remove_file(&path);
    }
}
