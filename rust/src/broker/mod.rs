//! The distribution broker: fault-tolerant multiplexing of one job
//! stream over N heterogeneous execution environments (paper §2.2, §4.6).
//!
//! OpenMOLE's promise is that the user never manages submission, failure
//! or stragglers — the platform does. [`Broker`] is that layer for this
//! reproduction: it implements [`Environment`] itself, so every engine
//! (generational GA, islands, the workflow scheduler) can sit on a fleet
//! of environments through the same one-line switch they use for a single
//! one. Per job it:
//!
//! * picks a backend through a pluggable [`DispatchPolicy`]
//!   (round-robin, least-in-flight, or the EWMA throughput/latency
//!   policy);
//! * tracks per-backend health and **circuit-breaks**: a backend whose
//!   windowed failure rate spikes is quarantined for a cooldown and its
//!   work re-routed (see [`health`]);
//! * **re-routes failures**: a terminally failed attempt is re-dispatched
//!   to another backend (up to [`RetryPolicy::max_attempts`]), paying an
//!   exponentially backed-off virtual resubmission penalty with
//!   deterministic per-job jitter;
//! * **enforces real-time bounds** ([`RetryPolicy`]): an attempt that
//!   produces nothing within `attempt_timeout_s` real seconds is abandoned
//!   as hung — health-penalised and re-routed like any infrastructure
//!   failure — and a job past `job_deadline_s` fails terminally with
//!   [`Error::Timeout`], so no [`JobHandle::wait`] can block forever on a
//!   hung backend;
//! * **speculatively resubmits stragglers** (OpenMOLE's oversubmission
//!   trick on EGI, opt-in via [`BrokerBuilder::speculation`] /
//!   `--speculate`): when a completed attempt's virtual duration exceeds
//!   a quantile of its completed peers, a clone is raced on another
//!   backend and the earlier virtual finish wins — the loser is
//!   cancelled in the accounting. The race is post-hoc on the virtual
//!   timeline (this repo's infrastructures are simulations around real
//!   local compute), so the clone does re-run the real computation.
//!
//! Failure taxonomy: only *infrastructure* errors (node failures,
//! walltime kills, environment/middleware errors) are re-routed and
//! charged to backend health. A task-level error (the job's own bug) is
//! surfaced immediately — re-running a deterministic failure elsewhere
//! wastes backends and would quarantine healthy ones.
//!
//! The [`journal`] module provides the JSONL checkpoint stream that makes
//! brokered runs resumable after a kill.

pub mod fairshare;
pub mod fault;
pub mod health;
pub mod journal;
pub mod policy;

pub use fairshare::{FairShare, TenantEnv};
pub use fault::{CrashWindow, FaultPlan, FaultyEnv, FlakyEnv, InjectedFaults};
pub use health::{CircuitConfig, Health};
pub use journal::{DegradedRows, Durability, Journal, ResumeState, SampleBlock, SweepEvent};
pub use policy::{
    BackendView, DispatchPolicy, EwmaPolicy, LeastInFlight, RetryPolicy, RoundRobin,
};

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::core::Context;
use crate::dsl::task::Task;
use crate::environment::{
    EnvStats, Environment, Job, JobHandle, JobReport, JobWaiter,
};
use crate::environment::cluster::BatchEnvironment;
use crate::environment::egi::EgiEnvironment;
use crate::environment::local::LocalEnvironment;
use crate::environment::ssh::SshEnvironment;
use crate::error::{Error, Result};
use crate::exec::ThreadPool;

/// Straggler-cloning configuration.
#[derive(Debug, Clone)]
pub struct SpeculationConfig {
    /// A completed job is a straggler when its virtual duration exceeds
    /// this quantile of completed peers.
    pub quantile: f64,
    /// Completed jobs required before speculation arms.
    pub min_samples: usize,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        SpeculationConfig {
            quantile: 0.95,
            min_samples: 20,
        }
    }
}

/// Broker-wide knobs.
#[derive(Debug, Clone)]
pub struct BrokerConfig {
    /// Attempt counts, real-time bounds and virtual backoff.
    pub retry: RetryPolicy,
    pub circuit: CircuitConfig,
    /// `None` disables straggler cloning.
    pub speculation: Option<SpeculationConfig>,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        BrokerConfig {
            retry: RetryPolicy::default(),
            circuit: CircuitConfig::default(),
            // opt-in: the discrete-event race is post-hoc, so a clone
            // re-runs the real computation — worth it for straggler-bound
            // virtual campaigns, pure overhead for cheap local tasks.
            // Enable with `.speculation(...)` or the CLI's `--speculate`.
            speculation: None,
        }
    }
}

/// Broker-level event counters (beyond the [`EnvStats`] every environment
/// reports).
#[derive(Debug, Clone, Default)]
pub struct BrokerCounters {
    /// Failed attempts re-dispatched onto a different backend.
    pub reroutes: u64,
    pub speculative_launched: u64,
    /// Speculative clones that finished (virtually) before their original.
    pub speculative_wins: u64,
    /// Losing copies written off in the accounting.
    pub speculative_cancelled: u64,
}

/// Public snapshot of one backend's broker-side state.
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    pub name: String,
    pub capacity: usize,
    pub in_flight: usize,
    pub completed: u64,
    pub failed: u64,
    pub ewma_duration_s: f64,
    pub quarantined: bool,
    pub quarantine_trips: u64,
}

#[derive(Default)]
struct BackendState {
    in_flight: usize,
    completed: u64,
    failed: u64,
    ewma_duration_s: f64,
    health: Health,
}

struct Backend {
    env: Arc<dyn Environment>,
    capacity: usize,
    state: Mutex<BackendState>,
}

/// Memoised straggler quantile: recomputed only after enough new
/// completions, so the completion hot path stays O(1) amortised.
struct ThresholdCache {
    computed_at: usize,
    value: f64,
}

struct BrokerCore {
    name: String,
    backends: Vec<Backend>,
    policy: Box<dyn DispatchPolicy>,
    cfg: BrokerConfig,
    /// Root of the deterministic backoff jitter (see [`RetryPolicy`]).
    seed: u64,
    stats: Mutex<EnvStats>,
    counters: Mutex<BrokerCounters>,
    /// Virtual durations of completed jobs (straggler quantile input).
    durations: Mutex<Vec<f64>>,
    threshold_cache: Mutex<Option<ThresholdCache>>,
}

const EWMA_ALPHA: f64 = 0.2;
const DURATION_WINDOW: usize = 4096;
/// Completions between straggler-quantile refreshes.
const THRESHOLD_REFRESH_EVERY: usize = 64;

impl BrokerCore {
    fn view(&self, index: usize, st: &BackendState) -> BackendView {
        BackendView {
            backend: index,
            capacity: self.backends[index].capacity,
            in_flight: st.in_flight,
            completed: st.completed,
            ewma_duration_s: st.ewma_duration_s,
            success_rate: st.health.success_rate(),
        }
    }

    /// Pick a backend (advancing quarantine clocks) and submit one
    /// attempt. `exclude` lists backends this job already failed on.
    fn dispatch(
        &self,
        task: &Arc<dyn Task>,
        ctx: &Context,
        release: f64,
        exclude: &[usize],
    ) -> (usize, JobHandle) {
        let mut views = Vec::with_capacity(self.backends.len());
        for (i, b) in self.backends.iter().enumerate() {
            let mut st = b.state.lock().unwrap();
            st.health.tick();
            if exclude.contains(&i) || st.health.quarantined() {
                continue;
            }
            views.push(self.view(i, &st));
        }
        if views.is_empty() {
            // every healthy backend is excluded: quarantined ones are
            // better than nothing
            for (i, b) in self.backends.iter().enumerate() {
                if exclude.contains(&i) {
                    continue;
                }
                let st = b.state.lock().unwrap();
                views.push(self.view(i, &st));
            }
        }
        if views.is_empty() {
            // the job failed everywhere already; give it its least-bad shot
            for (i, b) in self.backends.iter().enumerate() {
                let st = b.state.lock().unwrap();
                views.push(self.view(i, &st));
            }
        }
        let backend = views[self.policy.choose(&views)].backend;
        self.backends[backend].state.lock().unwrap().in_flight += 1;
        let job = Job::new(Arc::clone(task), ctx.clone()).released_at(release);
        (backend, self.backends[backend].env.submit(job))
    }

    /// Account one resolved attempt on its backend.
    fn record_attempt(&self, backend: usize, report: Option<&JobReport>) {
        let mut st = self.backends[backend].state.lock().unwrap();
        st.in_flight = st.in_flight.saturating_sub(1);
        st.health.record(report.is_some(), &self.cfg.circuit);
        match report {
            Some(r) => {
                st.completed += 1;
                let d = r.submit_delay_s + r.exec_s;
                st.ewma_duration_s = if st.completed == 1 {
                    d
                } else {
                    EWMA_ALPHA * d + (1.0 - EWMA_ALPHA) * st.ewma_duration_s
                };
            }
            None => st.failed += 1,
        }
    }

    /// Account one logically completed job (the winning attempt).
    fn record_job_success(&self, report: &JobReport, base_release: f64) {
        {
            let mut s = self.stats.lock().unwrap();
            s.completed += 1;
            s.virtual_cpu_s += report.exec_s;
            if report.virtual_end > s.virtual_makespan {
                s.virtual_makespan = report.virtual_end;
            }
        }
        let mut ds = self.durations.lock().unwrap();
        if ds.len() >= DURATION_WINDOW {
            let keep = DURATION_WINDOW / 2;
            let start = ds.len() - keep;
            ds.copy_within(start.., 0);
            ds.truncate(keep);
        }
        ds.push((report.virtual_end - base_release).max(0.0));
    }

    /// Current straggler threshold, if speculation is armed. The
    /// quantile is memoised and refreshed every
    /// [`THRESHOLD_REFRESH_EVERY`] completions, so the per-completion
    /// cost is a cache read, not a sort.
    fn straggler_threshold(&self) -> Option<f64> {
        let spec = self.cfg.speculation.as_ref()?;
        let ds = self.durations.lock().unwrap();
        let len = ds.len();
        if len < spec.min_samples.max(1) {
            return None;
        }
        let mut cache = self.threshold_cache.lock().unwrap();
        if let Some(c) = cache.as_ref() {
            // `computed_at > len` means the window was compacted since
            if c.computed_at <= len && len - c.computed_at < THRESHOLD_REFRESH_EVERY
            {
                return Some(c.value);
            }
        }
        let mut scratch = ds.clone();
        drop(ds);
        let idx = ((scratch.len() - 1) as f64 * spec.quantile.clamp(0.0, 1.0))
            .round() as usize;
        let (_, pivot, _) =
            scratch.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
        let value = *pivot;
        *cache = Some(ThresholdCache {
            computed_at: len,
            value,
        });
        Some(value)
    }
}

/// Is this failure the infrastructure's fault (worth retrying elsewhere
/// and charging to backend health) or the job's own (deterministic task
/// bug — retrying re-runs it for nothing, and a burst of bad jobs would
/// quarantine perfectly healthy backends)?
fn is_infrastructure_error(e: &Error) -> bool {
    matches!(
        e,
        Error::NodeFailure { .. }
            | Error::WallTimeExceeded(_)
            | Error::EnvironmentError { .. }
            | Error::Timeout { .. }
            | Error::GridScale(_)
            | Error::Io(_)
    )
}

enum Phase {
    /// One live attempt.
    Racing { backend: usize, handle: JobHandle },
    /// Primary finished as a straggler; a clone is racing its timeline.
    Speculating {
        best: Box<(Context, JobReport)>,
        spec_backend: usize,
        handle: JobHandle,
    },
    Finished,
}

struct JobState {
    phase: Phase,
    attempts_made: u32,
    failed_on: Vec<usize>,
    /// Real-time start of the current attempt (attempt-timeout clock).
    attempt_started: Instant,
    /// Real-time start of the job (job-deadline clock).
    job_started: Instant,
    /// Accumulated virtual backoff applied to re-dispatch releases.
    virtual_delay_s: f64,
}

/// The handle the broker returns: a small state machine that re-routes
/// failures and races speculative clones, advanced by (non-blocking)
/// polls.
struct BrokerJob {
    core: Arc<BrokerCore>,
    task: Arc<dyn Task>,
    ctx: Context,
    base_release: f64,
    /// Submission ordinal within this broker (jitter determinism).
    job_index: u64,
    state: Mutex<JobState>,
}

impl BrokerJob {
    /// Account a failed attempt (infrastructure error or timeout), then
    /// either re-dispatch with exponential backoff or fail terminally.
    /// The caller has already taken the attempt's handle out of the phase;
    /// on re-dispatch a fresh `Racing` phase is installed.
    fn retry_or_fail(
        &self,
        st: &mut JobState,
        backend: usize,
        e: Error,
        timed_out: bool,
    ) -> Option<Result<(Context, JobReport)>> {
        self.core.record_attempt(backend, None);
        st.failed_on.push(backend);
        let retry = &self.core.cfg.retry;
        let deadline_hit =
            st.job_started.elapsed().as_secs_f64() >= retry.job_deadline_s;
        {
            let mut s = self.core.stats.lock().unwrap();
            s.failed_attempts += 1;
            if timed_out {
                s.timed_out_attempts += 1;
            }
            if deadline_hit || st.attempts_made >= retry.max_attempts {
                s.failed_jobs += 1;
                return Some(Err(e));
            }
            s.resubmissions += 1;
        }
        self.core.counters.lock().unwrap().reroutes += 1;
        st.virtual_delay_s +=
            retry.backoff_s(st.attempts_made, self.core.seed, self.job_index);
        let (b, h) = self.core.dispatch(
            &self.task,
            &self.ctx,
            self.base_release + st.virtual_delay_s,
            &st.failed_on,
        );
        st.attempts_made += 1;
        st.attempt_started = Instant::now();
        st.phase = Phase::Racing {
            backend: b,
            handle: h,
        };
        None
    }

    /// Which real-time bound, if any, has this job tripped?
    fn tripped_bound(&self, st: &JobState) -> Option<(&'static str, f64)> {
        let retry = &self.core.cfg.retry;
        if st.job_started.elapsed().as_secs_f64() >= retry.job_deadline_s {
            Some(("job deadline", retry.job_deadline_s))
        } else if st.attempt_started.elapsed().as_secs_f64() >= retry.attempt_timeout_s
        {
            Some(("attempt timeout", retry.attempt_timeout_s))
        } else {
            None
        }
    }

    fn poll(&self) -> Option<Result<(Context, JobReport)>> {
        let mut st = self.state.lock().unwrap();
        let phase = std::mem::replace(&mut st.phase, Phase::Finished);
        match phase {
            Phase::Finished => Some(Err(Error::EnvironmentError {
                environment: self.core.name.clone(),
                message: "job result already consumed".into(),
            })),
            Phase::Racing { backend, handle } => match handle.try_wait() {
                None => {
                    let Some((what, after_s)) = self.tripped_bound(&st) else {
                        st.phase = Phase::Racing { backend, handle };
                        return None;
                    };
                    // the attempt hung: abandon its handle (dropped here)
                    // and treat the timeout as an infrastructure failure
                    let e = Error::Timeout {
                        environment: self.core.name.clone(),
                        what,
                        after_s,
                    };
                    self.retry_or_fail(&mut st, backend, e, true)
                }
                Some(Ok((ctx, report))) => {
                    self.core.record_attempt(backend, Some(&report));
                    let duration = report.virtual_end - self.base_release;
                    let threshold = self.core.straggler_threshold();
                    let speculate = threshold
                        .map(|t| duration > t && self.core.backends.len() > 1)
                        .unwrap_or(false);
                    if speculate {
                        // post-hoc race on the virtual timeline: the clone
                        // starts when the straggler was detected
                        // (base + threshold); the earlier virtual finish
                        // will win
                        let spec_release =
                            self.base_release + threshold.unwrap_or(0.0);
                        self.core.counters.lock().unwrap().speculative_launched +=
                            1;
                        let (sb, sh) = self.core.dispatch(
                            &self.task,
                            &self.ctx,
                            spec_release,
                            &[backend],
                        );
                        st.attempt_started = Instant::now();
                        st.phase = Phase::Speculating {
                            best: Box::new((ctx, report)),
                            spec_backend: sb,
                            handle: sh,
                        };
                        return None;
                    }
                    self.core.record_job_success(&report, self.base_release);
                    Some(Ok((ctx, report)))
                }
                Some(Err(e)) => {
                    if !is_infrastructure_error(&e) {
                        // the backend did its part — the task itself is
                        // broken. Surface immediately: no re-route, no
                        // health penalty.
                        let mut bst =
                            self.core.backends[backend].state.lock().unwrap();
                        bst.in_flight = bst.in_flight.saturating_sub(1);
                        drop(bst);
                        let mut s = self.core.stats.lock().unwrap();
                        s.failed_attempts += 1;
                        s.failed_jobs += 1;
                        return Some(Err(e));
                    }
                    self.retry_or_fail(&mut st, backend, e, false)
                }
            },
            Phase::Speculating {
                best,
                spec_backend,
                handle,
            } => match handle.try_wait() {
                None => {
                    if self.tripped_bound(&st).is_none() {
                        st.phase = Phase::Speculating {
                            best,
                            spec_backend,
                            handle,
                        };
                        return None;
                    }
                    // a hung clone never endangers the completed original:
                    // abandon it and surface the straggler's result
                    self.core.record_attempt(spec_backend, None);
                    self.core.stats.lock().unwrap().timed_out_attempts += 1;
                    let (ctx, report) = *best;
                    self.core.record_job_success(&report, self.base_release);
                    Some(Ok((ctx, report)))
                }
                Some(Ok((spec_ctx, spec_report))) => {
                    self.core.record_attempt(spec_backend, Some(&spec_report));
                    let (best_ctx, best_report) = *best;
                    let spec_won = spec_report.virtual_end < best_report.virtual_end;
                    {
                        let mut c = self.core.counters.lock().unwrap();
                        c.speculative_cancelled += 1; // exactly one copy loses
                        if spec_won {
                            c.speculative_wins += 1;
                        }
                    }
                    let (ctx, report) = if spec_won {
                        (spec_ctx, spec_report)
                    } else {
                        (best_ctx, best_report)
                    };
                    self.core.record_job_success(&report, self.base_release);
                    Some(Ok((ctx, report)))
                }
                Some(Err(_)) => {
                    // a failed clone never endangers the completed original
                    self.core.record_attempt(spec_backend, None);
                    let (ctx, report) = *best;
                    self.core.record_job_success(&report, self.base_release);
                    Some(Ok((ctx, report)))
                }
            },
        }
    }
}

impl Drop for BrokerJob {
    /// A handle abandoned mid-flight (caller aborted on another job's
    /// error) must release its backend's in-flight slot, or the policies
    /// see phantom load on that backend for the broker's lifetime.
    fn drop(&mut self) {
        let Ok(st) = self.state.get_mut() else { return };
        let backend = match &st.phase {
            Phase::Racing { backend, .. } => Some(*backend),
            Phase::Speculating { spec_backend, .. } => Some(*spec_backend),
            Phase::Finished => None,
        };
        if let Some(b) = backend {
            let mut bst = self.core.backends[b].state.lock().unwrap();
            bst.in_flight = bst.in_flight.saturating_sub(1);
        }
    }
}

impl JobWaiter for BrokerJob {
    fn wait(self: Box<Self>) -> Result<(Context, JobReport)> {
        loop {
            if let Some(r) = self.poll() {
                return r;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    fn try_wait(&self) -> Option<Result<(Context, JobReport)>> {
        self.poll()
    }
}

/// Builder for [`Broker`].
pub struct BrokerBuilder {
    name: String,
    backends: Vec<(Arc<dyn Environment>, usize)>,
    policy: Box<dyn DispatchPolicy>,
    cfg: BrokerConfig,
    seed: u64,
}

impl BrokerBuilder {
    pub fn backend(mut self, env: Arc<dyn Environment>, capacity: usize) -> Self {
        self.backends.push((env, capacity.max(1)));
        self
    }

    pub fn policy(mut self, policy: Box<dyn DispatchPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Replace the whole retry policy (attempts, timeouts, backoff).
    pub fn retry(mut self, retry: RetryPolicy) -> Self {
        self.cfg.retry = retry;
        self
    }

    pub fn max_attempts(mut self, n: u32) -> Self {
        self.cfg.retry.max_attempts = n.max(1);
        self
    }

    /// Base of the exponential virtual backoff between re-routes.
    pub fn resubmit_penalty(mut self, seconds: f64) -> Self {
        self.cfg.retry.backoff_base_s = seconds.max(0.0);
        self
    }

    /// Root of the deterministic backoff jitter.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn circuit(mut self, circuit: CircuitConfig) -> Self {
        self.cfg.circuit = circuit;
        self
    }

    pub fn speculation(mut self, spec: SpeculationConfig) -> Self {
        self.cfg.speculation = Some(spec);
        self
    }

    pub fn no_speculation(mut self) -> Self {
        self.cfg.speculation = None;
        self
    }

    pub fn build(self) -> Result<Broker> {
        if self.backends.is_empty() {
            return Err(Error::EnvironmentError {
                environment: self.name,
                message: "broker needs at least one backend".into(),
            });
        }
        Ok(Broker {
            core: Arc::new(BrokerCore {
                name: self.name,
                backends: self
                    .backends
                    .into_iter()
                    .map(|(env, capacity)| Backend {
                        env,
                        capacity,
                        state: Mutex::new(BackendState::default()),
                    })
                    .collect(),
                policy: self.policy,
                cfg: self.cfg,
                seed: self.seed,
                stats: Mutex::new(EnvStats::default()),
                counters: Mutex::new(BrokerCounters::default()),
                durations: Mutex::new(Vec::new()),
                threshold_cache: Mutex::new(None),
            }),
        })
    }
}

/// Fault-tolerant multi-environment dispatcher. See the module docs.
pub struct Broker {
    core: Arc<BrokerCore>,
}

impl Broker {
    /// Start building a broker (default policy: EWMA).
    pub fn builder(name: impl Into<String>) -> BrokerBuilder {
        BrokerBuilder {
            name: name.into(),
            backends: Vec::new(),
            policy: Box::new(EwmaPolicy::new()),
            cfg: BrokerConfig::default(),
            seed: 0,
        }
    }

    /// Build a broker from a CLI spec like
    /// `local:8,pbs:64,egi:biomed:2000` (the `--envs` flag).
    ///
    /// Entries are comma-separated:
    ///
    /// * `local[:n]` — this machine. All local backends share `pool`
    ///   (one machine, one worker set — see the oversubscription
    ///   regression test); `n` is a capacity hint for the policy.
    /// * `ssh[:host]:n`, `pbs:n`, `slurm:n`, `sge:n`, `oar:n`,
    ///   `condor:n`, `egi[:vo]:n` — the simulated remote environments.
    /// * any entry may end in `~p` (e.g. `pbs:32~0.2`) to wrap it in a
    ///   [`FlakyEnv`] that drops fraction `p` of submissions, or in a full
    ///   [`FaultPlan`] clause list (e.g. `pbs:32~drop=0.2;hang=0.01`) for
    ///   the composed chaos decorator — see the [`fault`] module doc for
    ///   the grammar.
    pub fn from_spec(
        spec: &str,
        pool: Arc<ThreadPool>,
        seed: u64,
    ) -> Result<Broker> {
        Self::spec_builder(spec, pool, seed)?.build()
    }

    /// Like [`Broker::from_spec`], but stop at the builder so callers can
    /// still override the policy or knobs (the CLI's `--policy` flag).
    pub fn spec_builder(
        spec: &str,
        pool: Arc<ThreadPool>,
        seed: u64,
    ) -> Result<BrokerBuilder> {
        let mut builder = Broker::builder(format!("broker[{spec}]")).seed(seed);
        let bad = |entry: &str, why: &str| Error::EnvironmentError {
            environment: "broker".into(),
            message: format!("bad --envs entry `{entry}`: {why}"),
        };
        for (i, entry) in spec.split(',').enumerate() {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let seed_i = seed.wrapping_add(0x9e37 * (i as u64 + 1));
            let (body, fault_spec) = match entry.split_once('~') {
                Some((b, f)) => (b, Some(f)),
                None => (entry, None),
            };
            let parts: Vec<&str> = body.split(':').collect();
            let n = |s: &str| {
                s.parse::<usize>()
                    .map_err(|_| bad(entry, "node count must be an integer"))
            };
            let (env, capacity): (Arc<dyn Environment>, usize) =
                match parts.as_slice() {
                    ["local"] => (
                        Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
                        pool.threads(),
                    ),
                    ["local", k] => (
                        Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
                        n(k)?.min(pool.threads()).max(1),
                    ),
                    ["ssh", k] => (
                        Arc::new(SshEnvironment::new(
                            "calc01",
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["ssh", host, k] => (
                        Arc::new(SshEnvironment::new(
                            host,
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["pbs", k] => (
                        Arc::new(BatchEnvironment::pbs(
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["slurm", k] => (
                        Arc::new(BatchEnvironment::slurm(
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["sge", k] => (
                        Arc::new(BatchEnvironment::sge(
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["oar", k] => (
                        Arc::new(BatchEnvironment::oar(
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["condor", k] => (
                        Arc::new(BatchEnvironment::condor(
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["egi", k] => (
                        Arc::new(EgiEnvironment::new(
                            "biomed",
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    ["egi", vo, k] => (
                        Arc::new(EgiEnvironment::new(
                            vo,
                            n(k)?,
                            Arc::clone(&pool),
                            seed_i,
                        )),
                        n(k)?,
                    ),
                    _ => return Err(bad(entry, "unknown environment kind")),
                };
            let env: Arc<dyn Environment> = match fault_spec {
                // a bare number keeps the historical drops-only meaning;
                // anything else is the full FaultPlan clause grammar
                Some(f) => match f.parse::<f64>() {
                    Ok(p) => Arc::new(FlakyEnv::new(env, p, seed_i ^ 0xF1A7)),
                    Err(_) => {
                        let plan = FaultPlan::parse(f)
                            .map_err(|e| bad(entry, &e.to_string()))?;
                        Arc::new(FaultyEnv::new(env, plan, seed_i ^ 0xF1A7))
                    }
                },
                None => env,
            };
            builder = builder.backend(env, capacity);
        }
        if builder.backends.is_empty() {
            return Err(Error::EnvironmentError {
                environment: "broker".into(),
                message: format!("--envs spec `{spec}` names no backends"),
            });
        }
        Ok(builder)
    }

    pub fn policy_name(&self) -> &str {
        self.core.policy.name()
    }

    pub fn backend_count(&self) -> usize {
        self.core.backends.len()
    }

    pub fn counters(&self) -> BrokerCounters {
        self.core.counters.lock().unwrap().clone()
    }

    /// Per-backend broker-side state (for reporting and tests).
    pub fn backend_snapshots(&self) -> Vec<BackendSnapshot> {
        self.core
            .backends
            .iter()
            .map(|b| {
                let st = b.state.lock().unwrap();
                BackendSnapshot {
                    name: b.env.name().to_string(),
                    capacity: b.capacity,
                    in_flight: st.in_flight,
                    completed: st.completed,
                    failed: st.failed,
                    ewma_duration_s: st.ewma_duration_s,
                    quarantined: st.health.quarantined(),
                    quarantine_trips: st.health.trips,
                }
            })
            .collect()
    }

    /// Total circuit-breaker trips across all backends.
    pub fn quarantine_trips(&self) -> u64 {
        self.backend_snapshots()
            .iter()
            .map(|s| s.quarantine_trips)
            .sum()
    }

    /// Underlying environment stats of backend `i` (e.g. for journals).
    pub fn backend_env_stats(&self, i: usize) -> Option<EnvStats> {
        self.core.backends.get(i).map(|b| b.env.stats())
    }
}

impl Environment for Broker {
    fn name(&self) -> &str {
        &self.core.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        let job_index = {
            let mut s = self.core.stats.lock().unwrap();
            s.submitted += 1;
            s.submitted - 1
        };
        let Job {
            task,
            context,
            virtual_release,
        } = job;
        let (backend, handle) =
            self.core.dispatch(&task, &context, virtual_release, &[]);
        let now = Instant::now();
        JobHandle::from_waiter(Box::new(BrokerJob {
            core: Arc::clone(&self.core),
            task,
            ctx: context,
            base_release: virtual_release,
            job_index,
            state: Mutex::new(JobState {
                phase: Phase::Racing { backend, handle },
                attempts_made: 1,
                failed_on: Vec::new(),
                attempt_started: now,
                job_started: now,
                virtual_delay_s: 0.0,
            }),
        }))
    }

    fn stats(&self) -> EnvStats {
        let mut s = self.core.stats.lock().unwrap().clone();
        // injected-fault counts live in the chaos decorators wrapped
        // around individual backends, never in the broker's own ledger —
        // fold them in so end-of-run summaries see real numbers
        s.injected_faults = self
            .core
            .backends
            .iter()
            .map(|b| b.env.stats().injected_faults)
            .sum();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, Context};
    use crate::dsl::task::ClosureTask;
    use crate::environment::run_all;

    fn task(cost: f64) -> Arc<ClosureTask> {
        let x = val_f64("x");
        Arc::new(
            ClosureTask::new("t", {
                let x = x.clone();
                move |ctx| {
                    Ok(Context::new().with(&x, ctx.get(&x).unwrap_or(0.0) + 1.0))
                }
            })
            .cost(cost),
        )
    }

    fn local_pair(pool: &Arc<ThreadPool>) -> BrokerBuilder {
        Broker::builder("b")
            .backend(Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))), 2)
            .backend(Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))), 2)
    }

    #[test]
    fn multiplexes_round_robin_across_backends() {
        let pool = Arc::new(ThreadPool::new(2));
        let broker = local_pair(&pool)
            .policy(Box::new(RoundRobin::new()))
            .no_speculation()
            .build()
            .unwrap();
        let results = run_all(
            &broker,
            (0..20).map(|_| Job::new(task(0.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap();
        }
        let s = broker.stats();
        assert_eq!(s.submitted, 20);
        assert_eq!(s.completed, 20);
        assert_eq!(s.failed_jobs, 0);
        assert_eq!(s.in_flight(), 0);
        let snaps = broker.backend_snapshots();
        assert_eq!(snaps[0].completed, 10, "round-robin must split evenly");
        assert_eq!(snaps[1].completed, 10);
    }

    #[test]
    fn reroutes_around_failing_backend_and_trips_breaker() {
        let pool = Arc::new(ThreadPool::new(2));
        let flaky: Arc<dyn Environment> = Arc::new(FlakyEnv::new(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
            1.0, // never succeeds
            3,
        ));
        let broker = Broker::builder("b")
            .backend(flaky, 2)
            .backend(
                Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
                2,
            )
            .policy(Box::new(RoundRobin::new()))
            .no_speculation()
            .build()
            .unwrap();
        let results = run_all(
            &broker,
            (0..30).map(|_| Job::new(task(0.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap(); // every job must be rescued by the healthy backend
        }
        let s = broker.stats();
        assert_eq!(s.completed, 30);
        assert_eq!(s.failed_jobs, 0);
        assert!(s.failed_attempts > 0);
        assert_eq!(
            s.failed_attempts,
            s.resubmissions + s.failed_jobs,
            "attempt ledger must balance"
        );
        assert!(broker.counters().reroutes > 0);
        assert!(
            broker.quarantine_trips() >= 1,
            "a 100%-failing backend must trip the breaker: {:?}",
            broker.backend_snapshots()
        );
        let snaps = broker.backend_snapshots();
        assert!(snaps[1].completed >= 15, "healthy backend absorbed the work");
    }

    #[test]
    fn terminal_failure_when_every_backend_fails() {
        let pool = Arc::new(ThreadPool::new(1));
        let flaky: Arc<dyn Environment> = Arc::new(FlakyEnv::new(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
            1.0,
            9,
        ));
        let broker = Broker::builder("b")
            .backend(flaky, 1)
            .max_attempts(3)
            .no_speculation()
            .build()
            .unwrap();
        let err = broker
            .submit(Job::new(task(0.0), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::NodeFailure { .. }));
        let s = broker.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed_jobs, 1);
        assert_eq!(s.failed_attempts, 3);
        assert_eq!(s.resubmissions, 2);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn speculation_clones_stragglers_and_accounts_the_race() {
        let pool = Arc::new(ThreadPool::new(2));
        // backend 0: two slots with real queueing (durations grow as the
        // queue deepens); backend 1: a fast local sink
        let broker = Broker::builder("b")
            .backend(
                Arc::new(SshEnvironment::new("slow", 2, Arc::clone(&pool), 1)),
                2,
            )
            .backend(
                Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
                2,
            )
            .policy(Box::new(RoundRobin::new()))
            .speculation(SpeculationConfig {
                quantile: 0.9,
                min_samples: 10,
            })
            .build()
            .unwrap();
        let results = run_all(
            &broker,
            (0..60).map(|_| Job::new(task(5.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap();
        }
        let c = broker.counters();
        assert!(
            c.speculative_launched > 0,
            "deep ssh queue must eventually exceed the p90 of peers: {c:?}"
        );
        // every resolved race cancels exactly one copy (unless the clone
        // itself failed), and wins are a subset of resolved races
        assert!(c.speculative_cancelled <= c.speculative_launched, "{c:?}");
        assert!(c.speculative_wins <= c.speculative_cancelled, "{c:?}");
        let s = broker.stats();
        assert_eq!(s.completed, 60, "speculation must not lose jobs");
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn dropped_handle_releases_in_flight() {
        let pool = Arc::new(ThreadPool::new(2));
        let broker = local_pair(&pool)
            .policy(Box::new(RoundRobin::new()))
            .no_speculation()
            .build()
            .unwrap();
        let h = broker.submit(Job::new(task(0.0), Context::new()));
        drop(h); // caller aborted without waiting
        // the pool job may still run; only the counter matters
        std::thread::sleep(Duration::from_millis(50));
        let snaps = broker.backend_snapshots();
        assert!(
            snaps.iter().all(|s| s.in_flight == 0),
            "abandoned handle leaked in-flight slots: {snaps:?}"
        );
    }

    #[test]
    fn task_error_surfaces_without_retry_or_health_penalty() {
        let pool = Arc::new(ThreadPool::new(1));
        let broker = local_pair(&pool)
            .policy(Box::new(RoundRobin::new()))
            .build()
            .unwrap();
        let boom = Arc::new(ClosureTask::new("boom", |_: &Context| {
            Err(Error::TaskFailed {
                task: "boom".into(),
                message: "deterministic task bug".into(),
            })
        }));
        let err = broker
            .submit(Job::new(boom, Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::TaskFailed { .. }));
        let s = broker.stats();
        assert_eq!(s.failed_attempts, 1, "no cross-backend re-execution");
        assert_eq!(s.resubmissions, 0);
        assert_eq!(s.failed_jobs, 1);
        assert_eq!(s.in_flight(), 0);
        for snap in broker.backend_snapshots() {
            assert_eq!(snap.failed, 0, "task bug must not poison backend health");
            assert!(!snap.quarantined);
        }
    }

    #[test]
    fn from_spec_parses_and_runs() {
        let pool = Arc::new(ThreadPool::new(2));
        let broker =
            Broker::from_spec("local:2,pbs:4,egi:biomed:8~0.5", pool, 42).unwrap();
        assert_eq!(broker.backend_count(), 3);
        let snaps = broker.backend_snapshots();
        assert!(snaps[0].name.starts_with("local"));
        assert!(snaps[1].name.starts_with("pbs"));
        assert!(snaps[2].name.starts_with("flaky"), "{}", snaps[2].name);
        let results = run_all(
            &broker,
            (0..10).map(|_| Job::new(task(1.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap();
        }
        assert_eq!(broker.stats().completed, 10);
    }

    #[test]
    fn from_spec_rejects_garbage() {
        let pool = Arc::new(ThreadPool::new(1));
        assert!(Broker::from_spec("mars:4", Arc::clone(&pool), 1).is_err());
        assert!(Broker::from_spec("pbs:abc", Arc::clone(&pool), 1).is_err());
        assert!(Broker::from_spec("pbs:4~x", Arc::clone(&pool), 1).is_err());
        assert!(
            Broker::from_spec("pbs:4~warp=0.5", Arc::clone(&pool), 1).is_err(),
            "unknown fault kind"
        );
        assert!(Broker::from_spec("", pool, 1).is_err(), "no backends");
    }

    #[test]
    fn from_spec_fault_plan_grammar_builds_chaos_backend() {
        let pool = Arc::new(ThreadPool::new(2));
        let broker =
            Broker::from_spec("local:2,local:2~drop=0.5;delay=0.1:30", pool, 42)
                .unwrap();
        let snaps = broker.backend_snapshots();
        assert!(snaps[1].name.starts_with("chaos["), "{}", snaps[1].name);
        let results = run_all(
            &broker,
            (0..20).map(|_| Job::new(task(0.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap(); // every drop is rescued by the healthy backend
        }
        assert_eq!(broker.stats().completed, 20);
    }

    fn fast_retry(max_attempts: u32, deadline_s: f64) -> RetryPolicy {
        RetryPolicy {
            max_attempts,
            attempt_timeout_s: 0.05,
            job_deadline_s: deadline_s,
            backoff_base_s: 1.0,
            backoff_max_s: 4.0,
            jitter: 0.5,
        }
    }

    #[test]
    fn hung_backend_times_out_reroutes_and_completes() {
        let pool = Arc::new(ThreadPool::new(2));
        let hung: Arc<dyn Environment> = Arc::new(FaultyEnv::new(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
            FaultPlan::new().hangs(1.0),
            1,
        ));
        let broker = Broker::builder("b")
            .backend(hung, 2)
            .backend(
                Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
                2,
            )
            .policy(Box::new(RoundRobin::new()))
            .retry(fast_retry(4, 10.0))
            .no_speculation()
            .build()
            .unwrap();
        let t0 = Instant::now();
        let results = run_all(
            &broker,
            (0..10).map(|_| Job::new(task(0.0), Context::new())).collect(),
        );
        for r in results {
            r.unwrap(); // every hung attempt must be rescued elsewhere
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "waits must be bounded by the attempt timeout"
        );
        let s = broker.stats();
        assert_eq!(s.completed, 10);
        assert!(s.timed_out_attempts > 0, "{s:?}");
        assert_eq!(
            s.failed_attempts,
            s.resubmissions + s.failed_jobs,
            "timeouts must keep the attempt ledger balanced: {s:?}"
        );
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn job_deadline_bounds_wait_on_fully_hung_fleet() {
        let pool = Arc::new(ThreadPool::new(1));
        let hung: Arc<dyn Environment> = Arc::new(FaultyEnv::new(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
            FaultPlan::new().hangs(1.0),
            2,
        ));
        let broker = Broker::builder("b")
            .backend(hung, 1)
            // attempts would allow retrying forever; the deadline stops it
            .retry(fast_retry(1000, 0.2))
            .no_speculation()
            .build()
            .unwrap();
        let t0 = Instant::now();
        let err = broker
            .submit(Job::new(task(0.0), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::Timeout { .. }), "{err}");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "wait() must return promptly after the deadline"
        );
        let s = broker.stats();
        assert_eq!(s.failed_jobs, 1);
        assert!(s.timed_out_attempts >= 1);
        assert_eq!(s.in_flight(), 0);
    }
}
