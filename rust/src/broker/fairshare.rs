//! Per-tenant fair-share admission in front of a shared [`Environment`]
//! (typically the [`Broker`](crate::broker::Broker)): the scheduling layer
//! that lets `molers serve` run many concurrent experiments over **one**
//! fleet without a 200k-row sweep starving a 100-row run.
//!
//! ## How it schedules
//!
//! Every tenant gets its own pending queue. Jobs submitted through a
//! [`TenantEnv`] handle are *not* forwarded to the inner environment
//! immediately — they wait in their tenant's queue until the pump picks
//! them by **weighted round-robin**: the cursor visits tenants in
//! registration order and forwards up to `weight` consecutive jobs from
//! each non-empty queue before moving on (weight 2 = twice the share of
//! a weight-1 tenant). At most `slots` jobs are in flight in the inner
//! environment at once, so the inner queue stays shallow and fairness
//! stays responsive: a small experiment's chunks interleave with a huge
//! sweep's instead of queueing behind all of it.
//!
//! ## No scheduler thread
//!
//! The pump runs inside the callers' own polling: every
//! [`JobHandle::try_wait`] / `wait` on a fair-share handle (and every
//! submit) advances forwarding, matching the non-blocking `try_wait`
//! discipline the rest of the crate uses. Dropping an unresolved handle
//! releases its slot (and its broker in-flight accounting via the inner
//! handle's own `Drop`).
//!
//! ## Cancellation
//!
//! A [`TenantEnv`] may carry a cancel token
//! ([`TenantEnv::with_cancel`]). Once the token is set, new submissions
//! and *queued* (not yet forwarded) jobs fail fast with an
//! `EnvironmentError` mentioning "cancelled"; jobs already forwarded run
//! to completion so the inner environment's accounting stays clean.
//!
//! Per-tenant [`EnvStats`] keep the crate-wide ledger invariant: once a
//! tenant's jobs are drained, `submitted == completed + failed_jobs`
//! (cancelled and abandoned jobs count as failed).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::core::Context;
use crate::environment::{EnvStats, Environment, Job, JobHandle, JobReport, JobWaiter};
use crate::error::{Error, Result};

/// One job parked between submission and forwarding.
struct PendingJob {
    /// Taken by the pump when the job is forwarded.
    job: Option<Job>,
    /// The inner environment's handle, once forwarded.
    inner: Option<JobHandle>,
    /// Cancelled (or abandoned) before forwarding — the pump skips it.
    cancelled: bool,
    /// Result delivered (or written off); guards double accounting.
    finished: bool,
}

type Slot = Arc<Mutex<PendingJob>>;

struct TenantState {
    name: String,
    weight: u64,
    queue: VecDeque<Slot>,
    stats: EnvStats,
}

struct Shared {
    tenants: Vec<TenantState>,
    /// Weighted round-robin position.
    cursor: usize,
    /// Consecutive dispatches left for the cursor tenant this round.
    burst_left: u64,
    /// Jobs currently forwarded into the inner environment.
    forwarded: usize,
}

/// Weighted round-robin fair-share gate over a shared environment. See
/// the module docs for the scheduling discipline.
pub struct FairShare {
    inner: Arc<dyn Environment>,
    slots: usize,
    state: Mutex<Shared>,
}

impl FairShare {
    /// Gate `inner` behind at most `slots` concurrently forwarded jobs.
    /// `slots` is clamped to at least 1; a good default is the fleet's
    /// total capacity.
    pub fn new(inner: Arc<dyn Environment>, slots: usize) -> Arc<Self> {
        Arc::new(FairShare {
            inner,
            slots: slots.max(1),
            state: Mutex::new(Shared {
                tenants: Vec::new(),
                cursor: 0,
                burst_left: 0,
                forwarded: 0,
            }),
        })
    }

    /// A submission handle for `name` with round-robin `weight` (clamped
    /// to ≥ 1). Handles for the same name share one queue and one stats
    /// ledger; a later call may raise the weight.
    pub fn tenant(self: &Arc<Self>, name: &str, weight: u64) -> TenantEnv {
        let tenant = {
            let mut st = self.state.lock().unwrap();
            match st.tenants.iter().position(|t| t.name == name) {
                Some(i) => {
                    st.tenants[i].weight = st.tenants[i].weight.max(weight.max(1));
                    i
                }
                None => {
                    st.tenants.push(TenantState {
                        name: name.to_string(),
                        weight: weight.max(1),
                        queue: VecDeque::new(),
                        stats: EnvStats::default(),
                    });
                    st.tenants.len() - 1
                }
            }
        };
        TenantEnv {
            fs: Arc::clone(self),
            tenant,
            label: format!("fair[{name}]:{}", self.inner.name()),
            cancel: None,
        }
    }

    /// Jobs parked in tenant queues (not yet forwarded).
    pub fn queued(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.tenants.iter().map(|t| t.queue.len()).sum()
    }

    /// Jobs currently forwarded into the inner environment.
    pub fn forwarded(&self) -> usize {
        self.state.lock().unwrap().forwarded
    }

    /// Pick the next queued job by weighted round-robin. Caller holds the
    /// state lock.
    fn next_slot(st: &mut Shared) -> Option<Slot> {
        let n = st.tenants.len();
        let mut scanned = 0;
        while scanned < n {
            let t = st.cursor % n;
            if st.tenants[t].queue.is_empty() {
                st.cursor = (st.cursor + 1) % n;
                st.burst_left = 0;
                scanned += 1;
                continue;
            }
            if st.burst_left == 0 {
                st.burst_left = st.tenants[t].weight.max(1);
            }
            let slot = st.tenants[t].queue.pop_front();
            st.burst_left -= 1;
            if st.burst_left == 0 {
                st.cursor = (st.cursor + 1) % n;
            }
            return slot;
        }
        None
    }

    /// Forward queued jobs while slots are free. Runs inside submit and
    /// every handle poll; never holds the shared lock across a forward.
    fn pump(&self) {
        loop {
            let slot = {
                let mut st = self.state.lock().unwrap();
                if st.forwarded >= self.slots {
                    return;
                }
                let Some(slot) = Self::next_slot(&mut st) else {
                    return;
                };
                st.forwarded += 1;
                slot
            };
            let mut p = slot.lock().unwrap();
            if p.cancelled || p.job.is_none() {
                // written off while queued — release the slot and move on
                drop(p);
                self.state.lock().unwrap().forwarded -= 1;
                continue;
            }
            let job = p.job.take().expect("guarded above");
            p.inner = Some(self.inner.submit(job));
        }
    }

    /// Account a forwarded job's terminal result and free its slot.
    fn complete(&self, tenant: usize, res: &Result<(Context, JobReport)>) {
        {
            let mut st = self.state.lock().unwrap();
            st.forwarded = st.forwarded.saturating_sub(1);
            let s = &mut st.tenants[tenant].stats;
            match res {
                Ok((_, r)) => {
                    s.completed += 1;
                    s.virtual_cpu_s += r.exec_s;
                    if r.virtual_end > s.virtual_makespan {
                        s.virtual_makespan = r.virtual_end;
                    }
                }
                Err(_) => {
                    s.failed_attempts += 1;
                    s.failed_jobs += 1;
                }
            }
        }
        self.pump();
    }

    /// Write off a job that will never deliver a result (cancelled while
    /// queued, or its handle dropped). `held_slot` releases a forwarded
    /// slot too.
    fn write_off(&self, tenant: usize, held_slot: bool) {
        {
            let mut st = self.state.lock().unwrap();
            if held_slot {
                st.forwarded = st.forwarded.saturating_sub(1);
            }
            let s = &mut st.tenants[tenant].stats;
            s.failed_attempts += 1;
            s.failed_jobs += 1;
        }
        self.pump();
    }

    fn tenant_stats(&self, tenant: usize) -> EnvStats {
        self.state.lock().unwrap().tenants[tenant].stats.clone()
    }
}

fn cancelled_error(label: &str) -> Error {
    Error::EnvironmentError {
        environment: label.to_string(),
        message: "cancelled: experiment cancel requested".into(),
    }
}

/// One tenant's submission face over a [`FairShare`]. Implements
/// [`Environment`], so a whole [`Experiment`](crate::workflow::Experiment)
/// can run on it unchanged while its jobs share the fleet fairly.
pub struct TenantEnv {
    fs: Arc<FairShare>,
    tenant: usize,
    label: String,
    cancel: Option<Arc<AtomicBool>>,
}

impl TenantEnv {
    /// Attach a cancel token: once set, new submissions and still-queued
    /// jobs fail fast (see the module docs).
    pub fn with_cancel(mut self, token: Arc<AtomicBool>) -> Self {
        self.cancel = Some(token);
        self
    }

    fn is_cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::Relaxed))
    }
}

impl Environment for TenantEnv {
    fn name(&self) -> &str {
        &self.label
    }

    fn submit(&self, job: Job) -> JobHandle {
        {
            let mut st = self.fs.state.lock().unwrap();
            st.tenants[self.tenant].stats.submitted += 1;
        }
        if self.is_cancelled() {
            self.fs.write_off(self.tenant, false);
            return JobHandle::ready(Err(cancelled_error(&self.label)));
        }
        let slot: Slot = Arc::new(Mutex::new(PendingJob {
            job: Some(job),
            inner: None,
            cancelled: false,
            finished: false,
        }));
        {
            let mut st = self.fs.state.lock().unwrap();
            st.tenants[self.tenant].queue.push_back(Arc::clone(&slot));
        }
        self.fs.pump();
        JobHandle::from_waiter(Box::new(FairJob {
            fs: Arc::clone(&self.fs),
            tenant: self.tenant,
            label: self.label.clone(),
            cancel: self.cancel.clone(),
            slot,
        }))
    }

    fn stats(&self) -> EnvStats {
        self.fs.tenant_stats(self.tenant)
    }
}

/// The waiter behind a fair-share handle: pumps on every poll, delegates
/// to the inner handle once forwarded, fails fast when cancelled while
/// still queued.
struct FairJob {
    fs: Arc<FairShare>,
    tenant: usize,
    label: String,
    cancel: Option<Arc<AtomicBool>>,
    slot: Slot,
}

impl FairJob {
    fn poll(&self) -> Option<Result<(Context, JobReport)>> {
        self.fs.pump();
        if self
            .cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::Relaxed))
        {
            // fail fast only while queued; a forwarded job runs out so the
            // inner environment's ledger reconciles
            let mut p = self.slot.lock().unwrap();
            if p.inner.is_none() && !p.finished {
                p.cancelled = true;
                p.finished = true;
                p.job = None;
                drop(p);
                self.fs.write_off(self.tenant, false);
                return Some(Err(cancelled_error(&self.label)));
            }
        }
        let res = {
            let p = self.slot.lock().unwrap();
            match &p.inner {
                Some(h) => h.try_wait(),
                None => return None, // still queued
            }
        };
        let res = res?;
        {
            let mut p = self.slot.lock().unwrap();
            p.inner = None;
            p.finished = true;
        }
        self.fs.complete(self.tenant, &res);
        Some(res)
    }
}

impl JobWaiter for FairJob {
    fn wait(self: Box<Self>) -> Result<(Context, JobReport)> {
        loop {
            if let Some(r) = self.poll() {
                return r;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    fn try_wait(&self) -> Option<Result<(Context, JobReport)>> {
        self.poll()
    }
}

impl Drop for FairJob {
    /// An abandoned handle must release its slot (and write the job off)
    /// or the gate leaks capacity for the server's lifetime.
    fn drop(&mut self) {
        let held_slot = {
            let Ok(mut p) = self.slot.lock() else { return };
            if p.finished {
                return;
            }
            p.cancelled = true;
            p.finished = true;
            p.job = None;
            p.inner.take().is_some() // inner handle drops here
        };
        self.fs.write_off(self.tenant, held_slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_str, Context};
    use crate::dsl::task::ClosureTask;
    use crate::environment::local::LocalEnvironment;
    use crate::error::Result;

    /// Inner env that records the submission order of each job's `tag`
    /// context variable and completes instantly.
    struct TagRecorder {
        order: Mutex<Vec<String>>,
    }

    impl TagRecorder {
        fn new() -> Arc<Self> {
            Arc::new(TagRecorder {
                order: Mutex::new(Vec::new()),
            })
        }
    }

    impl Environment for TagRecorder {
        fn name(&self) -> &str {
            "recorder"
        }

        fn submit(&self, job: Job) -> JobHandle {
            let tag = job
                .context
                .get(&val_str("tag"))
                .unwrap_or_else(|_| "?".into());
            self.order.lock().unwrap().push(tag);
            JobHandle::ready(Ok((
                Context::new(),
                JobReport {
                    environment: "recorder".into(),
                    node: "n0".into(),
                    attempts: 1,
                    submit_delay_s: 0.0,
                    queue_s: 0.0,
                    exec_s: 1.0,
                    virtual_start: 0.0,
                    virtual_end: 1.0,
                    real_exec: Duration::ZERO,
                },
            )))
        }

        fn stats(&self) -> EnvStats {
            EnvStats::default()
        }
    }

    fn tagged(tag: &str) -> Job {
        let mut ctx = Context::new();
        ctx.set(&val_str("tag"), tag.to_string());
        let task = ClosureTask::new("noop", |_ctx: &Context| Ok(Context::new()));
        Job::new(Arc::new(task), ctx)
    }

    fn drain(mut handles: Vec<JobHandle>) {
        while !handles.is_empty() {
            handles.retain(|h| h.try_wait().is_none());
        }
    }

    #[test]
    fn round_robin_interleaves_a_late_small_tenant() {
        let recorder = TagRecorder::new();
        let fs = FairShare::new(Arc::clone(&recorder) as Arc<dyn Environment>, 1);
        let big = fs.tenant("big", 1);
        let small = fs.tenant("small", 1);

        // the big sweep floods the gate first, then the small run arrives
        let mut handles: Vec<JobHandle> =
            (0..40).map(|i| big.submit(tagged(&format!("big{i}")))).collect();
        handles.extend((0..4).map(|i| small.submit(tagged(&format!("small{i}")))));
        drain(handles);

        let order = recorder.order.lock().unwrap().clone();
        assert_eq!(order.len(), 44);
        // with slots=1 the forward order is pure round-robin once both
        // queues are non-empty: small's last job must be forwarded long
        // before big's queue drains (FIFO would put it at position 43)
        let last_small = order.iter().position(|t| t == "small3").unwrap();
        assert!(
            last_small <= 10,
            "small tenant starved: last job forwarded at {last_small} in {order:?}"
        );
        // per-tenant ledgers reconcile
        assert_eq!(big.stats().completed, 40);
        assert_eq!(small.stats().completed, 4);
        assert_eq!(fs.queued(), 0);
        assert_eq!(fs.forwarded(), 0);
    }

    #[test]
    fn weights_scale_the_share() {
        let recorder = TagRecorder::new();
        let fs = FairShare::new(Arc::clone(&recorder) as Arc<dyn Environment>, 1);
        let heavy = fs.tenant("heavy", 3);
        let light = fs.tenant("light", 1);

        let mut handles: Vec<JobHandle> =
            (0..12).map(|i| heavy.submit(tagged(&format!("h{i}")))).collect();
        handles.extend((0..12).map(|i| light.submit(tagged(&format!("l{i}")))));
        drain(handles);

        let order = recorder.order.lock().unwrap().clone();
        // among the first 8 forwards, heavy gets ~3x light's share
        let heavy_early =
            order[..8].iter().filter(|t| t.starts_with('h')).count();
        assert_eq!(heavy_early, 6, "3:1 weighting in {order:?}");
    }

    #[test]
    fn cancel_fails_queued_jobs_fast_and_ledger_reconciles() {
        let recorder = TagRecorder::new();
        let fs = FairShare::new(Arc::clone(&recorder) as Arc<dyn Environment>, 1);
        let token = Arc::new(AtomicBool::new(false));
        let t = fs.tenant("t", 1).with_cancel(Arc::clone(&token));

        let mut handles: Vec<JobHandle> =
            (0..6).map(|i| t.submit(tagged(&format!("j{i}")))).collect();
        token.store(true, Ordering::Relaxed);
        // wait newest-first: with slots=1 only j0 was forwarded, so the
        // five still-queued jobs must all fail fast
        handles.reverse();
        let mut errors = 0;
        for h in handles {
            if h.wait().is_err() {
                errors += 1;
            }
        }
        assert_eq!(errors, 5, "queued jobs must fail fast on cancel");
        // post-cancel submissions fail immediately
        assert!(t.submit(tagged("late")).wait().is_err());
        let s = t.stats();
        assert_eq!(s.submitted, 7);
        assert_eq!(s.completed + s.failed_jobs, 7, "ledger reconciles: {s:?}");
        assert_eq!(fs.forwarded(), 0);
    }

    #[test]
    fn dropped_handles_release_their_slots() {
        let recorder = TagRecorder::new();
        let fs = FairShare::new(Arc::clone(&recorder) as Arc<dyn Environment>, 2);
        let t = fs.tenant("t", 1);
        let handles: Vec<JobHandle> =
            (0..5).map(|i| t.submit(tagged(&format!("j{i}")))).collect();
        drop(handles);
        assert_eq!(fs.forwarded(), 0, "abandoned handles must free slots");
        let s = t.stats();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed + s.failed_jobs, 5);
        // the gate still works afterwards
        assert!(t.submit(tagged("after")).wait().is_ok());
    }

    /// The cancel/Drop race: tenant A is cancelled while it still has
    /// queued jobs, in the same window where tenant B's handle is
    /// dropped mid-poll (after forwarding, before resolution). Both
    /// tenants' slots must come back — a leak here would wedge the
    /// shared gate for every other tenant.
    #[test]
    fn cancel_and_drop_race_releases_both_slots() {
        let recorder = TagRecorder::new();
        let fs = FairShare::new(Arc::clone(&recorder) as Arc<dyn Environment>, 2);
        let token = Arc::new(AtomicBool::new(false));
        let a = fs.tenant("a", 1).with_cancel(Arc::clone(&token));
        let b = fs.tenant("b", 1);

        // fill both slots (one per tenant), then queue more behind them
        let a_handles: Vec<JobHandle> =
            (0..4).map(|i| a.submit(tagged(&format!("a{i}")))).collect();
        let b_handles: Vec<JobHandle> =
            (0..4).map(|i| b.submit(tagged(&format!("b{i}")))).collect();

        // b's first handle was forwarded (its slot is held); poll it once
        // so the pump advances, then drop ALL of b's handles mid-flight
        let _ = b_handles[0].try_wait();
        drop(b_handles);
        // and cancel a while its later jobs are still queued
        token.store(true, Ordering::Relaxed);
        let mut cancelled = 0;
        for h in a_handles {
            if h.wait().is_err() {
                cancelled += 1;
            }
        }
        assert!(
            cancelled >= 3,
            "still-queued jobs must fail fast on cancel, got {cancelled}"
        );

        // both tenants' slots are back and the ledgers reconcile
        assert_eq!(fs.forwarded(), 0, "a leaked slot wedges the gate");
        assert_eq!(fs.queued(), 0);
        let sa = a.stats();
        assert_eq!(sa.submitted, 4);
        assert_eq!(sa.completed + sa.failed_jobs, 4, "tenant a ledger: {sa:?}");
        let sb = b.stats();
        assert_eq!(sb.submitted, 4);
        assert_eq!(sb.completed + sb.failed_jobs, 4, "tenant b ledger: {sb:?}");

        // the gate still schedules a third tenant afterwards
        let c = fs.tenant("c", 1);
        assert!(c.submit(tagged("after")).wait().is_ok());
        assert_eq!(fs.forwarded(), 0);
    }

    /// Two real sweep-shaped workloads over one local environment: both
    /// complete and per-tenant stats stay separate.
    #[test]
    fn real_environment_end_to_end() {
        let inner = Arc::new(LocalEnvironment::new(2));
        let fs = FairShare::new(inner as Arc<dyn Environment>, 2);
        let a = fs.tenant("a", 1);
        let b = fs.tenant("b", 2);
        let job = || {
            let task = ClosureTask::new("work", |_ctx: &Context| Ok(Context::new()));
            Job::new(Arc::new(task), Context::new())
        };
        let ha: Vec<JobHandle> = (0..10).map(|_| a.submit(job())).collect();
        let hb: Vec<JobHandle> = (0..10).map(|_| b.submit(job())).collect();
        let results: Vec<Result<_>> =
            ha.into_iter().chain(hb).map(JobHandle::wait).collect();
        assert!(results.iter().all(|r| r.is_ok()));
        assert_eq!(a.stats().completed, 10);
        assert_eq!(b.stats().completed, 10);
        assert_eq!(fs.queued(), 0);
        assert_eq!(fs.forwarded(), 0);
    }
}
