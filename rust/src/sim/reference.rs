//! Executable specification of the ant model: the pre-optimisation kernel,
//! kept verbatim as the golden oracle for the optimised hot path.
//!
//! `tests/sim_golden.rs` asserts that [`super::ants`] — after the §Perf
//! refactor (persistent diffuse scratch, in-place ant updates, incremental
//! food counters) — reproduces this module's trajectories **bit for bit**
//! across seeds. The two implementations share [`Field`] storage and
//! [`Rng`], but this one deliberately keeps the original shape: a fresh
//! `vec!` per diffuse, a cloned `Ant` per ant per tick, and full-grid
//! `sum_where` scans in the fitness latch. It is test infrastructure, not
//! a fast path — never call it from an evaluator.

use crate::sim::ants::{AntParams, HALF, SOURCES, WORLD};
use crate::sim::world::Field;
use crate::util::Rng;

const NEST_RADIUS: f64 = 5.0;
const SOURCE_RADIUS: f64 = 5.0;
const CHEMICAL_DROP: f64 = 60.0;
const SNIFF_LOW: f64 = 0.05;
const SNIFF_HIGH: f64 = 2.0;
const WIGGLE_MAX: f64 = 40.0;

#[derive(Debug, Clone)]
struct Ant {
    x: f64,
    y: f64,
    heading: f64,
    carrying: bool,
}

/// The original simulation state: no incremental counters — food per
/// source is recomputed by scanning the grid.
pub struct ReferenceAntSim {
    pub params: AntParams,
    pub food: Field,
    pub chemical: Field,
    pub nest: Vec<bool>,
    pub nest_scent: Field,
    pub source_id: Vec<u8>,
    ants: Vec<Ant>,
    rng: Rng,
    pub tick: u32,
    pub final_ticks: [u32; 3],
}

impl ReferenceAntSim {
    /// `setup`, identical draw order to the optimised twin.
    pub fn new(params: AntParams, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut food = Field::new(WORLD);
        let mut nest_scent = Field::new(WORLD);
        let mut nest = vec![false; WORLD * WORLD];
        let mut source_id = vec![0u8; WORLD * WORLD];

        for row in 0..WORLD {
            for col in 0..WORLD {
                let x = col as f64 - f64::from(HALF);
                let y = row as f64 - f64::from(HALF);
                let d_nest = (x * x + y * y).sqrt();
                nest[row * WORLD + col] = d_nest < NEST_RADIUS;
                nest_scent.set(row, col, 200.0 - d_nest);
                for (i, (sx, sy)) in SOURCES.iter().enumerate() {
                    let d = ((x - sx).powi(2) + (y - sy).powi(2)).sqrt();
                    if d < SOURCE_RADIUS {
                        source_id[row * WORLD + col] = i as u8 + 1;
                    }
                }
            }
        }
        for row in 0..WORLD {
            for col in 0..WORLD {
                if source_id[row * WORLD + col] > 0 {
                    food.set(row, col, f64::from(rng.usize(2) as u32 + 1));
                }
            }
        }

        let n_ants = params.population.round().max(0.0) as usize;
        let ants = (0..n_ants)
            .map(|_| Ant {
                x: 0.0,
                y: 0.0,
                heading: rng.range(0.0, 360.0),
                carrying: false,
            })
            .collect();

        ReferenceAntSim {
            params,
            food,
            chemical: Field::new(WORLD),
            nest,
            nest_scent,
            source_id,
            ants,
            rng,
            tick: 0,
            final_ticks: [0; 3],
        }
    }

    fn in_world(x: f64, y: f64) -> bool {
        x.abs() <= f64::from(HALF) && y.abs() <= f64::from(HALF)
    }

    fn scent_at_angle(field: &Field, ant: &Ant, angle: f64) -> f64 {
        let rad = (ant.heading + angle).to_radians();
        field.get_xy(ant.x + rad.sin(), ant.y + rad.cos())
    }

    fn uphill(field: &Field, ant: &mut Ant) {
        let ahead = Self::scent_at_angle(field, ant, 0.0);
        let right = Self::scent_at_angle(field, ant, 45.0);
        let left = Self::scent_at_angle(field, ant, -45.0);
        if right > ahead || left > ahead {
            ant.heading += if right > left { 45.0 } else { -45.0 };
        }
    }

    /// The original per-tick `vec!`-allocating diffuse (same separable
    /// arithmetic as `Field::diffuse`, without the persistent buffers).
    fn diffuse_fresh(field: &mut Field, d: f64) {
        let n = field.size;
        let share = d / 8.0;
        let mut hsum = vec![0.0f64; n * n];
        for r in 0..n {
            for c in 0..n {
                let left = if c > 0 { field.get(r, c - 1) } else { 0.0 };
                let right = if c + 1 < n { field.get(r, c + 1) } else { 0.0 };
                hsum[r * n + c] = left + field.get(r, c) + right;
            }
        }
        let mut next = vec![0.0f64; n * n];
        for r in 0..n {
            let vcnt = if r == 0 || r + 1 == n { 2.0 } else { 3.0 };
            for c in 0..n {
                let hcnt = if c == 0 || c + 1 == n { 2.0 } else { 3.0 };
                let count = hcnt * vcnt - 1.0;
                let above = if r > 0 { hsum[(r - 1) * n + c] } else { 0.0 };
                let below = if r + 1 < n { hsum[(r + 1) * n + c] } else { 0.0 };
                let v = field.get(r, c);
                let neigh = above + hsum[r * n + c] + below - v;
                next[r * n + c] = v - v * d * count / 8.0 + share * neigh;
            }
        }
        for r in 0..n {
            for c in 0..n {
                field.set(r, c, next[r * n + c]);
            }
        }
    }

    /// One `go` tick in the original clone-per-ant, scan-per-source shape.
    pub fn step(&mut self) {
        self.tick += 1;
        let n = self.ants.len();
        for i in 0..n {
            if i as u32 >= self.tick {
                break;
            }
            let mut ant = self.ants[i].clone();
            let (row, col) = self.food.patch(ant.x, ant.y);
            if !ant.carrying {
                if self.food.get(row, col) > 0.0 {
                    self.food.set(row, col, self.food.get(row, col) - 1.0);
                    ant.carrying = true;
                    ant.heading += 180.0;
                } else {
                    let chem = self.chemical.get(row, col);
                    if (SNIFF_LOW..SNIFF_HIGH).contains(&chem) {
                        Self::uphill(&self.chemical, &mut ant);
                    }
                }
            } else if self.nest[row * WORLD + col] {
                ant.carrying = false;
                ant.heading += 180.0;
            } else {
                self.chemical.add_xy(ant.x, ant.y, CHEMICAL_DROP);
                Self::uphill(&self.nest_scent, &mut ant);
            }
            ant.heading += self.rng.range(0.0, WIGGLE_MAX);
            ant.heading -= self.rng.range(0.0, WIGGLE_MAX);
            let rad = ant.heading.to_radians();
            let (nx, ny) = (ant.x + rad.sin(), ant.y + rad.cos());
            if !Self::in_world(nx, ny) {
                ant.heading += 180.0;
            }
            let rad = ant.heading.to_radians();
            let (nx, ny) = (ant.x + rad.sin(), ant.y + rad.cos());
            if Self::in_world(nx, ny) {
                ant.x = nx;
                ant.y = ny;
            }
            ant.heading = ant.heading.rem_euclid(360.0);
            self.ants[i] = ant;
        }

        Self::diffuse_fresh(&mut self.chemical, self.params.diffusion_rate / 100.0);
        self.chemical
            .scale((100.0 - self.params.evaporation_rate) / 100.0);

        for s in 0..3u8 {
            if self.final_ticks[s as usize] == 0 {
                let remaining = self
                    .food
                    .sum_where(|r, c| self.source_id[r * WORLD + c] == s + 1);
                if remaining <= 0.0 {
                    self.final_ticks[s as usize] = self.tick;
                }
            }
        }
    }

    /// Remaining food per source, by grid scan.
    pub fn remaining(&self) -> [f64; 3] {
        let mut out = [0.0; 3];
        for (s, slot) in out.iter_mut().enumerate() {
            *slot = self
                .food
                .sum_where(|r, c| self.source_id[r * WORLD + c] == s as u8 + 1);
        }
        out
    }

    pub fn ant_positions(&self) -> Vec<(f64, f64, bool)> {
        self.ants.iter().map(|a| (a.x, a.y, a.carrying)).collect()
    }

    /// Run to `max_ticks` (or all sources empty); same contract as
    /// [`super::ants::AntSim::run`].
    pub fn run(&mut self, max_ticks: u32) -> [f64; 3] {
        while self.tick < max_ticks {
            self.step();
            if self.final_ticks.iter().all(|&t| t > 0) {
                break;
            }
        }
        let mut fit = [0.0; 3];
        for (i, slot) in fit.iter_mut().enumerate() {
            *slot = if self.final_ticks[i] == 0 {
                f64::from(max_ticks)
            } else {
                f64::from(self.final_ticks[i])
            };
        }
        fit
    }
}

/// Evaluate the three objectives with the reference kernel.
pub fn evaluate(params: AntParams, seed: u64, max_ticks: u32) -> [f64; 3] {
    let mut sim = ReferenceAntSim::new(params, seed);
    sim.run(max_ticks)
}
