//! Pure-Rust port of the NetLogo "Ants" foraging model (paper §4.1).
//!
//! This is the coordinator-side twin of `python/compile/model.py`: same
//! world geometry, same behaviours, same fitness definition. It serves
//! three purposes:
//!
//! 1. **artifact-free baseline evaluator** — workflows and tests run
//!    without `make artifacts`;
//! 2. **cross-validation oracle** — integration tests compare its fitness
//!    statistics against the PJRT-executed JAX model (different RNGs, so
//!    the comparison is distributional, not bitwise);
//! 3. **figure rendering** — Figures 1–2 of the paper are regenerated from
//!    its state (see [`super::render`]).
//!
//! Unlike the JAX port (synchronous agent updates for vectorisation), this
//! twin follows NetLogo's *sequential* `ask turtles`, which makes it the
//! closer-to-reference implementation; DESIGN.md §7 discusses the
//! difference.

use crate::sim::world::Field;
use crate::util::Rng;

pub const WORLD: usize = 71;
pub const HALF: i32 = 35;
pub const MAX_TICKS_DEFAULT: u32 = 1000;
const NEST_RADIUS: f64 = 5.0;
const SOURCE_RADIUS: f64 = 5.0;
/// Food source centres, NetLogo coords — identical to model.py SOURCES.
pub const SOURCES: [(f64, f64); 3] = [(21.0, 0.0), (-21.0, -21.0), (-28.0, 28.0)];
const CHEMICAL_DROP: f64 = 60.0;
const SNIFF_LOW: f64 = 0.05;
const SNIFF_HIGH: f64 = 2.0;
const WIGGLE_MAX: f64 = 40.0;

/// Model parameters (the calibration genome of §4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AntParams {
    pub population: f64,
    pub diffusion_rate: f64,
    pub evaporation_rate: f64,
}

impl Default for AntParams {
    /// Paper Listing 2 defaults.
    fn default() -> Self {
        AntParams {
            population: 125.0,
            diffusion_rate: 50.0,
            evaporation_rate: 50.0,
        }
    }
}

#[derive(Debug, Clone)]
struct Ant {
    x: f64,
    y: f64,
    heading: f64,
    carrying: bool,
}

/// The full mutable simulation state.
pub struct AntSim {
    pub params: AntParams,
    pub food: Field,
    pub chemical: Field,
    pub nest: Vec<bool>,
    pub nest_scent: Field,
    pub source_id: Vec<u8>,
    ants: Vec<Ant>,
    rng: Rng,
    pub tick: u32,
    /// First tick each source emptied (0 = not yet).
    pub final_ticks: [u32; 3],
    /// Remaining food per source, maintained incrementally: initialised
    /// from the setup grid and decremented at pickup. Replaces the
    /// per-source full-grid `sum_where` scans the fitness latch and
    /// `remaining()` used to run every tick (§Perf tentpole). Values are
    /// integer-valued f64s throughout, so the latch threshold is exact.
    food_left: [f64; 3],
}

impl AntSim {
    /// `setup`: nest, scent gradient, three food sources with 1-or-2 food
    /// units per patch.
    pub fn new(params: AntParams, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut food = Field::new(WORLD);
        let mut nest_scent = Field::new(WORLD);
        let mut nest = vec![false; WORLD * WORLD];
        let mut source_id = vec![0u8; WORLD * WORLD];

        for row in 0..WORLD {
            for col in 0..WORLD {
                let x = col as f64 - f64::from(HALF);
                let y = row as f64 - f64::from(HALF);
                let d_nest = (x * x + y * y).sqrt();
                nest[row * WORLD + col] = d_nest < NEST_RADIUS;
                nest_scent.set(row, col, 200.0 - d_nest);
                for (i, (sx, sy)) in SOURCES.iter().enumerate() {
                    let d = ((x - sx).powi(2) + (y - sy).powi(2)).sqrt();
                    if d < SOURCE_RADIUS {
                        source_id[row * WORLD + col] = i as u8 + 1;
                    }
                }
            }
        }
        let mut food_left = [0.0f64; 3];
        for row in 0..WORLD {
            for col in 0..WORLD {
                let s = source_id[row * WORLD + col];
                if s > 0 {
                    // set food one-of [1 2]
                    let units = f64::from(rng.usize(2) as u32 + 1);
                    food.set(row, col, units);
                    food_left[s as usize - 1] += units;
                }
            }
        }

        let n_ants = params.population.round().max(0.0) as usize;
        let ants = (0..n_ants)
            .map(|_| Ant {
                x: 0.0,
                y: 0.0,
                heading: rng.range(0.0, 360.0),
                carrying: false,
            })
            .collect();

        AntSim {
            params,
            food,
            chemical: Field::new(WORLD),
            nest,
            nest_scent,
            source_id,
            ants,
            rng,
            tick: 0,
            final_ticks: [0; 3],
            food_left,
        }
    }

    pub fn n_ants(&self) -> usize {
        self.ants.len()
    }

    pub fn ant_positions(&self) -> Vec<(f64, f64, bool)> {
        self.ants.iter().map(|a| (a.x, a.y, a.carrying)).collect()
    }

    fn in_world(x: f64, y: f64) -> bool {
        x.abs() <= f64::from(HALF) && y.abs() <= f64::from(HALF)
    }

    fn scent_at_angle(field: &Field, ant: &Ant, angle: f64) -> f64 {
        let rad = (ant.heading + angle).to_radians();
        field.get_xy(ant.x + rad.sin(), ant.y + rad.cos())
    }

    /// `uphill-chemical` / `uphill-nest-scent`.
    fn uphill(field: &Field, ant: &mut Ant) {
        let ahead = Self::scent_at_angle(field, ant, 0.0);
        let right = Self::scent_at_angle(field, ant, 45.0);
        let left = Self::scent_at_angle(field, ant, -45.0);
        if right > ahead || left > ahead {
            ant.heading += if right > left { 45.0 } else { -45.0 };
        }
    }

    /// One `go` tick: sequential per-ant behaviour, then diffuse/evaporate,
    /// then the fitness latch (Listing 1's `compute-fitness`).
    ///
    /// Hot-path shape (§Perf tentpole): ants are mutated in place through
    /// disjoint field borrows (no per-ant clone/write-back), and the latch
    /// reads the incrementally maintained per-source counters instead of
    /// rescanning the grid. Behaviour — RNG draw order included — is
    /// bit-identical to the original (`tests/sim_golden.rs`).
    pub fn step(&mut self) {
        self.tick += 1;
        // `if who >= ticks [ stop ]` — staggered departure
        let active = (self.tick as usize).min(self.ants.len());
        let AntSim {
            food,
            chemical,
            nest,
            nest_scent,
            source_id,
            ants,
            rng,
            food_left,
            ..
        } = self;
        for ant in ants[..active].iter_mut() {
            let (row, col) = food.patch(ant.x, ant.y);
            if !ant.carrying {
                // look-for-food
                if food.get(row, col) > 0.0 {
                    food.set(row, col, food.get(row, col) - 1.0);
                    let s = source_id[row * WORLD + col];
                    if s > 0 {
                        food_left[s as usize - 1] -= 1.0;
                    }
                    ant.carrying = true;
                    ant.heading += 180.0;
                } else {
                    let chem = chemical.get(row, col);
                    if (SNIFF_LOW..SNIFF_HIGH).contains(&chem) {
                        Self::uphill(chemical, ant);
                    }
                }
            } else if nest[row * WORLD + col] {
                // return-to-nest: arrived — drop food, turn around
                ant.carrying = false;
                ant.heading += 180.0;
            } else {
                // return-to-nest: drop pheromone, climb the nest gradient
                chemical.add_xy(ant.x, ant.y, CHEMICAL_DROP);
                Self::uphill(nest_scent, ant);
            }
            // wiggle
            ant.heading += rng.range(0.0, WIGGLE_MAX);
            ant.heading -= rng.range(0.0, WIGGLE_MAX);
            // fd 1, bouncing off the world edge
            let rad = ant.heading.to_radians();
            let (nx, ny) = (ant.x + rad.sin(), ant.y + rad.cos());
            if !Self::in_world(nx, ny) {
                ant.heading += 180.0;
            }
            let rad = ant.heading.to_radians();
            let (nx, ny) = (ant.x + rad.sin(), ant.y + rad.cos());
            if Self::in_world(nx, ny) {
                ant.x = nx;
                ant.y = ny;
            }
            ant.heading = ant.heading.rem_euclid(360.0);
        }

        // patch updates
        self.chemical.diffuse(self.params.diffusion_rate / 100.0);
        self.chemical
            .scale((100.0 - self.params.evaporation_rate) / 100.0);

        // fitness latch on the incremental counters (== the grid scan sums
        // bit-for-bit: both are exact integer-valued f64 arithmetic)
        for s in 0..3 {
            if self.final_ticks[s] == 0 && self.food_left[s] <= 0.0 {
                self.final_ticks[s] = self.tick;
            }
        }
    }

    /// Remaining food per source (the incremental counters).
    pub fn remaining(&self) -> [f64; 3] {
        self.food_left
    }

    /// Run to `max_ticks` (or all sources empty) and return the three
    /// objectives: first-empty tick per source, `max_ticks` if never.
    pub fn run(&mut self, max_ticks: u32) -> [f64; 3] {
        while self.tick < max_ticks {
            self.step();
            if self.final_ticks.iter().all(|&t| t > 0) {
                break;
            }
        }
        let mut fit = [0.0; 3];
        for (i, slot) in fit.iter_mut().enumerate() {
            *slot = if self.final_ticks[i] == 0 {
                f64::from(max_ticks)
            } else {
                f64::from(self.final_ticks[i])
            };
        }
        fit
    }
}

/// Convenience: evaluate the three objectives for a parameter set.
pub fn evaluate(params: AntParams, seed: u64, max_ticks: u32) -> [f64; 3] {
    AntSim::new(params, seed).run(max_ticks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn good_params() -> AntParams {
        // persistent trails: the NetLogo slider defaults
        AntParams {
            population: 125.0,
            diffusion_rate: 50.0,
            evaporation_rate: 10.0,
        }
    }

    #[test]
    fn setup_builds_three_sources() {
        let sim = AntSim::new(AntParams::default(), 1);
        let rem = sim.remaining();
        for (s, r) in rem.iter().enumerate() {
            assert!(*r > 0.0, "source {s} empty at setup");
        }
        assert_eq!(sim.n_ants(), 125);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = evaluate(good_params(), 9, 400);
        let b = evaluate(good_params(), 9, 400);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_vary_outcome() {
        let a = evaluate(good_params(), 1, 400);
        let b = evaluate(good_params(), 2, 400);
        assert_ne!(a, b);
    }

    #[test]
    fn forages_and_empties_near_source() {
        let fit = evaluate(good_params(), 42, 800);
        assert!(fit[0] < 800.0, "near source never emptied: {fit:?}");
        assert!(fit[0] <= fit[2], "near source should empty first: {fit:?}");
    }

    #[test]
    fn zero_population_never_forages() {
        let p = AntParams {
            population: 0.0,
            ..good_params()
        };
        assert_eq!(evaluate(p, 3, 100), [100.0, 100.0, 100.0]);
    }

    #[test]
    fn food_is_monotone_nonincreasing() {
        let mut sim = AntSim::new(good_params(), 5);
        let mut last = sim.food.sum();
        for _ in 0..200 {
            sim.step();
            let now = sim.food.sum();
            assert!(now <= last + 1e-9);
            last = now;
        }
    }

    #[test]
    fn incremental_counters_match_grid_scans() {
        let mut sim = AntSim::new(good_params(), 13);
        for t in 0..300 {
            sim.step();
            let scan: Vec<f64> = (0..3u8)
                .map(|s| {
                    sim.food
                        .sum_where(|r, c| sim.source_id[r * WORLD + c] == s + 1)
                })
                .collect();
            let counters = sim.remaining();
            for s in 0..3 {
                assert_eq!(
                    counters[s].to_bits(),
                    scan[s].to_bits(),
                    "source {s} diverged at tick {t}"
                );
            }
        }
    }

    #[test]
    fn chemical_stays_nonnegative() {
        let mut sim = AntSim::new(good_params(), 6);
        for _ in 0..200 {
            sim.step();
        }
        for r in 0..WORLD {
            for c in 0..WORLD {
                assert!(sim.chemical.get(r, c) >= 0.0);
            }
        }
    }

    #[test]
    fn ants_remain_in_world() {
        let mut sim = AntSim::new(good_params(), 7);
        for _ in 0..300 {
            sim.step();
        }
        for (x, y, _) in sim.ant_positions() {
            assert!(x.abs() <= 35.0 && y.abs() <= 35.0);
        }
    }

    #[test]
    fn staggered_departure() {
        let mut sim = AntSim::new(good_params(), 8);
        for _ in 0..4 {
            sim.step();
        }
        let moved = sim
            .ant_positions()
            .iter()
            .filter(|(x, y, _)| x.abs() > 0.0 || y.abs() > 0.0)
            .count();
        assert!(moved <= 4);
    }
}
