//! NetLogo-like grid-world substrate: square patch fields with the
//! `diffuse` primitive. The Rust twin of the L1/L2 Python world — used as
//! the artifact-free baseline evaluator, for cross-validation, and to
//! render the paper's Figures 1–2.

/// A square field of f64 patch values with NetLogo coordinates
/// (`-half ..= half` on both axes, non-wrapping).
#[derive(Clone, Debug)]
pub struct Field {
    pub size: usize,
    data: Vec<f64>,
    /// Scratch for `diffuse`: horizontal 3-window sums. Sized lazily on the
    /// first diffuse and reused for every later tick (§Perf: the evaluate
    /// hot path must not allocate per tick).
    hsum: Vec<f64>,
    /// Double buffer for `diffuse`: written each tick, then swapped with
    /// `data` — no per-tick `vec!` allocation.
    next: Vec<f64>,
}

impl Field {
    pub fn new(size: usize) -> Self {
        Field {
            size,
            data: vec![0.0; size * size],
            hsum: Vec::new(),
            next: Vec::new(),
        }
    }

    pub fn half(&self) -> i32 {
        (self.size / 2) as i32
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        row * self.size + col
    }

    /// Clamp NetLogo (x, y) to grid (row, col).
    #[inline]
    pub fn patch(&self, x: f64, y: f64) -> (usize, usize) {
        let half = self.half();
        let col = (x.round() as i32 + half).clamp(0, self.size as i32 - 1) as usize;
        let row = (y.round() as i32 + half).clamp(0, self.size as i32 - 1) as usize;
        (row, col)
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> f64 {
        self.data[self.idx(row, col)]
    }

    #[inline]
    pub fn get_xy(&self, x: f64, y: f64) -> f64 {
        let (r, c) = self.patch(x, y);
        self.get(r, c)
    }

    #[inline]
    pub fn set(&mut self, row: usize, col: usize, v: f64) {
        let i = self.idx(row, col);
        self.data[i] = v;
    }

    #[inline]
    pub fn add_xy(&mut self, x: f64, y: f64, v: f64) {
        let (r, c) = self.patch(x, y);
        let i = self.idx(r, c);
        self.data[i] += v;
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn max(&self) -> f64 {
        self.data.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sum of values where `mask` returns true.
    pub fn sum_where(&self, mask: impl Fn(usize, usize) -> bool) -> f64 {
        let mut total = 0.0;
        for r in 0..self.size {
            for c in 0..self.size {
                if mask(r, c) {
                    total += self.get(r, c);
                }
            }
        }
        total
    }

    /// NetLogo `diffuse field d` with non-wrapping edges: each patch gives
    /// `d/8` of its value to every *existing* Moore neighbour and keeps the
    /// shares destined for missing neighbours. Mirrors
    /// `kernels/ref.py::diffuse_evaporate_ref` exactly (evaporation aside).
    ///
    /// Implementation (§Perf item 4): the 8-neighbour sum is computed as a
    /// separable box filter — horizontal 3-sums per row, then a sliding
    /// 3-row vertical window, minus the centre — turning the naive 9
    /// reads/patch into ~3 amortised.
    /// Zero-allocation on the steady state: `hsum`/`next` are persistent
    /// scratch buffers (sized on first use), and the result is swapped into
    /// `data` instead of replacing the allocation. The arithmetic — order
    /// of operations included — is identical to the original per-tick
    /// `vec!` version, so trajectories are bit-for-bit unchanged (pinned by
    /// `tests/sim_golden.rs`).
    pub fn diffuse(&mut self, d: f64) {
        let n = self.size;
        let share = d / 8.0;
        if self.hsum.len() != n * n {
            // first diffuse on this field: size the scratch once
            self.hsum.resize(n * n, 0.0);
            self.next.resize(n * n, 0.0);
        }
        // horizontal 3-window sums (zero beyond the edge)
        let data = &self.data;
        let hsum = &mut self.hsum;
        for r in 0..n {
            let row = &data[r * n..(r + 1) * n];
            let h = &mut hsum[r * n..(r + 1) * n];
            for c in 0..n {
                let left = if c > 0 { row[c - 1] } else { 0.0 };
                let right = if c + 1 < n { row[c + 1] } else { 0.0 };
                h[c] = left + row[c] + right;
            }
        }
        let hsum = &self.hsum;
        let next = &mut self.next;
        for r in 0..n {
            // in-world neighbour counts are separable too:
            // (3-window width) x (3-window height) - 1
            let vcnt = if r == 0 || r + 1 == n { 2.0 } else { 3.0 };
            for c in 0..n {
                let hcnt = if c == 0 || c + 1 == n { 2.0 } else { 3.0 };
                let count = hcnt * vcnt - 1.0;
                let above = if r > 0 { hsum[(r - 1) * n + c] } else { 0.0 };
                let below = if r + 1 < n { hsum[(r + 1) * n + c] } else { 0.0 };
                let v = data[r * n + c];
                let neigh = above + hsum[r * n + c] + below - v;
                next[r * n + c] = v - v * d * count / 8.0 + share * neigh;
            }
        }
        // every element of `next` was just written; the stale values left
        // in the swapped-out buffer are overwritten on the following tick
        std::mem::swap(&mut self.data, &mut self.next);
    }

    /// Uniform decay: `field *= keep`.
    pub fn scale(&mut self, keep: f64) {
        for v in &mut self.data {
            *v *= keep;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patch_mapping_clamps() {
        let f = Field::new(71);
        assert_eq!(f.patch(0.0, 0.0), (35, 35));
        assert_eq!(f.patch(-35.0, -35.0), (0, 0));
        assert_eq!(f.patch(99.0, 99.0), (70, 70));
    }

    #[test]
    fn diffuse_conserves_mass() {
        let mut f = Field::new(11);
        f.set(5, 5, 100.0);
        f.set(0, 0, 50.0);
        let before = f.sum();
        f.diffuse(0.7);
        assert!((f.sum() - before).abs() < 1e-9);
    }

    #[test]
    fn diffuse_point_source_interior() {
        let mut f = Field::new(5);
        f.set(2, 2, 8.0);
        f.diffuse(1.0);
        assert!(f.get(2, 2).abs() < 1e-12);
        assert!((f.get(1, 1) - 1.0).abs() < 1e-12);
        assert!((f.get(2, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diffuse_corner_keeps_leftover() {
        let mut f = Field::new(5);
        f.set(0, 0, 8.0);
        f.diffuse(1.0);
        // 3 neighbours get 1 each; corner keeps 5/8 of 8 = 5
        assert!((f.get(0, 0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn repeated_diffuse_reuses_scratch_and_stays_exact() {
        // one field diffused 5 times (scratch reused across ticks) vs a
        // freshly constructed field per tick carrying the same values: the
        // persistent buffers must not leak state between ticks
        let mut reused = Field::new(9);
        reused.set(4, 4, 100.0);
        reused.set(0, 8, 7.0);
        for step in 0..5 {
            let mut fresh = Field::new(9);
            for r in 0..9 {
                for c in 0..9 {
                    fresh.set(r, c, reused.get(r, c));
                }
            }
            reused.diffuse(0.6);
            fresh.diffuse(0.6);
            for r in 0..9 {
                for c in 0..9 {
                    assert_eq!(
                        reused.get(r, c).to_bits(),
                        fresh.get(r, c).to_bits(),
                        "divergence at step {step}, patch ({r}, {c})"
                    );
                }
            }
        }
    }

    #[test]
    fn scale_decays() {
        let mut f = Field::new(3);
        f.set(1, 1, 10.0);
        f.scale(0.9);
        assert!((f.get(1, 1) - 9.0).abs() < 1e-12);
    }
}
