//! Agent-based simulation substrate + the Rust twin of the ant model.

pub mod ants;
pub mod reference;
pub mod render;
pub mod world;

pub use ants::{evaluate, AntParams, AntSim};
pub use world::Field;
