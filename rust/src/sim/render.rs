//! Regenerate the paper's Figures 1–2: a view of the ant world (nest,
//! three food sources, chemical trails, ants), as ASCII art for the
//! terminal and as a PPM image for files.

use crate::sim::ants::{AntSim, WORLD};

/// ASCII rendering: `N` nest, `1`..`3` food sources (with food left),
/// `a`/`A` ants (empty/carrying), `.`:`+`:`*` chemical intensity.
pub fn ascii(sim: &AntSim) -> String {
    let mut grid = vec![vec![' '; WORLD]; WORLD];
    for r in 0..WORLD {
        for c in 0..WORLD {
            let chem = sim.chemical.get(r, c);
            grid[r][c] = if chem > 10.0 {
                '*'
            } else if chem > 1.0 {
                '+'
            } else if chem > 0.05 {
                '.'
            } else {
                ' '
            };
            let src = sim.source_id[r * WORLD + c];
            if src > 0 && sim.food.get(r, c) > 0.0 {
                grid[r][c] = char::from(b'0' + src);
            }
            if sim.nest[r * WORLD + c] {
                grid[r][c] = 'N';
            }
        }
    }
    for (x, y, carrying) in sim.ant_positions() {
        let (r, c) = sim.food.patch(x, y);
        grid[r][c] = if carrying { 'A' } else { 'a' };
    }
    // flip vertically so +y is up, like NetLogo's view
    let mut out = String::with_capacity(WORLD * (WORLD + 1));
    for row in grid.iter().rev() {
        out.extend(row.iter());
        out.push('\n');
    }
    out
}

/// Binary PPM (P6) rendering at `scale` pixels per patch.
pub fn ppm(sim: &AntSim, scale: usize) -> Vec<u8> {
    let w = WORLD * scale;
    let mut pixels = vec![[0u8, 0, 0]; WORLD * WORLD];
    for r in 0..WORLD {
        for c in 0..WORLD {
            let chem = sim.chemical.get(r, c);
            let g = (chem * 12.0).min(255.0) as u8;
            let mut px = [0, g, 0];
            let src = sim.source_id[r * WORLD + c];
            if src > 0 && sim.food.get(r, c) > 0.0 {
                px = match src {
                    1 => [70, 130, 255],
                    2 => [255, 200, 60],
                    _ => [230, 60, 200],
                };
            }
            if sim.nest[r * WORLD + c] {
                px = [150, 90, 60];
            }
            pixels[r * WORLD + c] = px;
        }
    }
    for (x, y, _) in sim.ant_positions() {
        let (r, c) = sim.food.patch(x, y);
        pixels[r * WORLD + c] = [255, 0, 0];
    }
    let mut out = format!("P6\n{w} {w}\n255\n").into_bytes();
    for r in (0..WORLD).rev() {
        for _ in 0..scale {
            for c in 0..WORLD {
                for _ in 0..scale {
                    out.extend_from_slice(&pixels[r * WORLD + c]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ants::AntParams;

    #[test]
    fn ascii_shows_nest_and_sources() {
        let sim = AntSim::new(AntParams::default(), 1);
        let art = ascii(&sim);
        assert!(art.contains('N'));
        assert!(art.contains('1'));
        assert!(art.contains('2'));
        assert!(art.contains('3'));
        assert_eq!(art.lines().count(), WORLD);
    }

    #[test]
    fn ppm_has_correct_header_and_size() {
        let sim = AntSim::new(AntParams::default(), 1);
        let img = ppm(&sim, 2);
        let header = format!("P6\n{0} {0}\n255\n", WORLD * 2);
        assert!(img.starts_with(header.as_bytes()));
        assert_eq!(img.len(), header.len() + (WORLD * 2) * (WORLD * 2) * 3);
    }
}
