//! Minimal CLI argument parser (clap is not vendored in this image).
//!
//! Supports `program <subcommand> --key value --flag` with typed getters
//! and automatic usage errors — enough surface for the `molers` launcher
//! and the bench binaries. The [`front`] module turns parsed arguments
//! into MoleDSL v2 [`crate::workflow::Experiment`]s, one per subcommand.

pub mod front;

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty option name `--`".into());
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Every `--key value` option, in sorted key order — lets the thin
    /// client forward its parsed options over the wire verbatim.
    pub fn options(&self) -> impl Iterator<Item = (&str, &str)> {
        self.options.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// Every bare `--flag`, in parse order.
    pub fn flag_names(&self) -> &[String] {
        &self.flags
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("island --islands 2000 --seed 42 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("island"));
        assert_eq!(a.usize("islands", 0).unwrap(), 2000);
        assert_eq!(a.u64("seed", 0).unwrap(), 42);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --env=egi --mu=200");
        assert_eq!(a.get("env"), Some("egi"));
        assert_eq!(a.usize("mu", 0).unwrap(), 200);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize("mu", 10).unwrap(), 10);
        assert_eq!(a.f64("rate", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("env", "local"), "local");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("run --mu abc");
        assert!(a.usize("mu", 0).is_err());
    }

    #[test]
    fn negative_option_values() {
        let a = parse("run --x -3.5");
        assert_eq!(a.f64("x", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn positional_args() {
        let a = parse("render out.ppm --ticks 100");
        assert_eq!(a.positional(), &["out.ppm".to_string()]);
    }

    #[test]
    fn options_and_flags_are_enumerable() {
        let a = parse("client submit explore --n 200 --chunk 8 --degraded-ok");
        let opts: Vec<(String, String)> = a
            .options()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        assert_eq!(
            opts,
            vec![
                ("chunk".to_string(), "8".to_string()),
                ("n".to_string(), "200".to_string())
            ]
        );
        assert_eq!(a.flag_names(), &["degraded-ok".to_string()]);
        assert_eq!(a.positional(), &["submit".to_string(), "explore".to_string()]);
    }
}
