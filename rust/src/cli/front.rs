//! The `molers` CLI front: one function per subcommand, each parsing
//! [`Args`] into a MoleDSL v2 [`Experiment`]. `main.rs` only dispatches
//! and prints — every run is constructed and executed through the same
//! typed API the examples use, with zero engine-specific env/journal/
//! resume plumbing left in the launcher.

use std::sync::Arc;

use crate::broker::RetryPolicy;
use crate::cli::Args;
use crate::core::{val_f64, val_u32, Context, Val};
use crate::dsl::hook::{TableFormat, ToStringHook};
use crate::dsl::task::ClosureTask;
use crate::error::{Error, Result};
use crate::evolution::evaluator::{Evaluator, PooledEvaluator, ReplicatedEvaluator};
use crate::evolution::generational::Nsga2Config;
use crate::evolution::island::IslandConfig;
use crate::exploration::sampling::{
    Factor, FullFactorial, LhsSampling, Sampling, SobolSampling, UniformSampling,
};
use crate::exploration::statistics::StatisticTask;
use crate::runtime::best_available_evaluator;
use crate::util::json::Json;
use crate::util::stats::Descriptor;
use crate::workflow::experiment::{
    DirectSampling, EnvSpec, Experiment, IslandEvolution, Nsga2Evolution,
    Replication, SingleRun,
};

/// Surface an `Args` parse error as a config error.
fn num<T>(r: std::result::Result<T, String>) -> Result<T> {
    r.map_err(Error::Config)
}

/// Parse a byte count with an optional `k`/`m`/`g` suffix (powers of
/// 1024), e.g. `--mem-budget 64m`. Public so `molers serve` rejects a
/// bad client-supplied budget at submission time with the same message.
pub fn parse_bytes(flag: &str, s: &str) -> Result<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (digits, mult) = match t.chars().last() {
        Some('k') => (&t[..t.len() - 1], 1u64 << 10),
        Some('m') => (&t[..t.len() - 1], 1u64 << 20),
        Some('g') => (&t[..t.len() - 1], 1u64 << 30),
        _ => (t.as_str(), 1),
    };
    let n: u64 = digits.trim().parse().map_err(|_| {
        Error::Config(format!("{flag} expects BYTES[k|m|g], got `{s}`"))
    })?;
    let bytes = n.checked_mul(mult).ok_or_else(|| {
        Error::Config(format!("{flag} `{s}` overflows a 64-bit byte count"))
    })?;
    if bytes == 0 {
        return Err(Error::Config(format!(
            "{flag} expects a positive byte count, got `{s}`"
        )));
    }
    Ok(bytes)
}

/// `--timeout` (real seconds per job, also capping the per-attempt
/// timeout), `--max-retries` (re-dispatches after the first attempt) and
/// `--backoff` (base virtual seconds) over [`RetryPolicy::default`].
/// `None` when no override flag is present. Public so `molers serve` can
/// apply the same overrides to its shared fleet.
pub fn retry_overrides(args: &Args) -> Result<Option<RetryPolicy>> {
    if args.get("timeout").is_none()
        && args.get("max-retries").is_none()
        && args.get("backoff").is_none()
    {
        return Ok(None);
    }
    let mut r = RetryPolicy::default();
    if args.get("timeout").is_some() {
        let t = num(args.f64("timeout", r.job_deadline_s))?;
        if !(t.is_finite() && t > 0.0) {
            return Err(Error::Config(format!(
                "--timeout expects positive real seconds, got `{t}`"
            )));
        }
        r.job_deadline_s = t;
        r.attempt_timeout_s = r.attempt_timeout_s.min(t);
    }
    if args.get("max-retries").is_some() {
        let n = num(args.usize("max-retries", 3))?;
        r.max_attempts = n as u32 + 1;
    }
    if args.get("backoff").is_some() {
        let b = num(args.f64("backoff", r.backoff_base_s))?;
        if !(b.is_finite() && b >= 0.0) {
            return Err(Error::Config(format!(
                "--backoff expects non-negative virtual seconds, got `{b}`"
            )));
        }
        r.backoff_base_s = b;
        r.backoff_max_s = r.backoff_max_s.max(b);
    }
    Ok(Some(r))
}

/// `--envs SPEC` (a brokered fleet, with `--policy` and `--speculate`)
/// wins over the single-environment `--env NAME`. Retry/deadline flags
/// are enforced in the broker's waiter state machine, so their presence
/// promotes a single environment to a one-backend fleet. Public so
/// `molers reexec` can interpret env-override flags exactly the way the
/// original subcommand would have.
pub fn env_spec(args: &Args, default_env: &str, nodes: usize) -> Result<EnvSpec> {
    let retry = retry_overrides(args)?;
    if let Some(spec) = args.get("envs") {
        Ok(EnvSpec::Fleet {
            spec: spec.to_string(),
            policy: args.get_or("policy", "ewma").to_string(),
            speculate: args.flag("speculate"),
            retry,
        })
    } else if retry.is_some() {
        let name = args.get_or("env", default_env);
        Ok(EnvSpec::Fleet {
            spec: format!("{name}:{nodes}"),
            policy: args.get_or("policy", "ewma").to_string(),
            speculate: args.flag("speculate"),
            retry,
        })
    } else {
        Ok(EnvSpec::Single {
            name: args.get_or("env", default_env).to_string(),
            nodes,
        })
    }
}

/// Apply the flags every subcommand shares: `--seed`, `--journal`,
/// `--resume`, `--durability`. Both paths are forwarded verbatim — the
/// `Experiment` rejects the `--journal` + `--resume` combination (and
/// `--journal` on methods that never checkpoint) with a clear error
/// instead of the CLI silently dropping a flag.
fn with_common(mut exp: Experiment, args: &Args) -> Result<Experiment> {
    exp = exp.seed(num(args.u64("seed", 42))?);
    if let Some(path) = args.get("resume") {
        exp = exp.resume(path);
    }
    if let Some(path) = args.get("journal") {
        exp = exp.journal(path);
    }
    if let Some(d) = args.get("durability") {
        let d = crate::broker::Durability::parse(d).ok_or_else(|| {
            Error::Config(format!(
                "invalid --durability `{d}` (always|batch[:N]|os)"
            ))
        })?;
        exp = exp.durability(d);
    }
    Ok(exp)
}

/// Flags that select *where* a run executes or *how* it persists, not
/// *what* it computes. A provenance manifest strips these from the
/// recorded argv: the env fleet is recorded structurally (and compat-
/// checked at reexec time), persistence is deliberately absent (`molers
/// reexec` must reproduce the result **without** the original journal),
/// and `--seed`/`--out` are re-injected from dedicated manifest fields.
const NON_METHOD_KEYS: &[&str] = &[
    "out",
    "journal",
    "resume",
    "durability",
    "spill-dir",
    "seed",
    "env",
    "envs",
    "nodes",
    "policy",
    "speculate",
    "timeout",
    "max-retries",
    "backoff",
];

/// The method-configuration argv a provenance manifest records: every
/// option and flag of the original invocation except [`NON_METHOD_KEYS`].
/// Options come out sorted by key (the `Args` iteration order), so the
/// recorded argv is canonical regardless of the original flag order.
pub fn provenance_argv(args: &Args) -> Vec<String> {
    let mut argv = Vec::new();
    for (k, v) in args.options() {
        if !NON_METHOD_KEYS.contains(&k) {
            argv.push(format!("--{k}"));
            argv.push(v.to_string());
        }
    }
    for f in args.flag_names() {
        if !NON_METHOD_KEYS.contains(&f.as_str()) {
            argv.push(format!("--{f}"));
        }
    }
    argv
}

/// Dispatch a method name to its subcommand front — the server-side
/// entry for client submissions, so a wire payload builds exactly the
/// [`Experiment`] the equivalent CLI invocation would.
pub fn by_name(method: &str, args: &Args) -> Result<Experiment> {
    match method {
        "run" => run(args),
        "explore" => explore(args),
        "replicate" => replicate(args),
        "calibrate" => calibrate(args),
        "island" => island(args),
        other => Err(Error::Config(format!(
            "unknown method `{other}` (run|explore|replicate|calibrate|island)"
        ))),
    }
}

/// The calibration genome: (diffusion, evaporation) bounds and the three
/// median objectives of paper Listing 4.
pub fn genome_bounds() -> (Val<f64>, Val<f64>, Vec<Val<f64>>) {
    (
        val_f64("gDiffusionRate"),
        val_f64("gEvaporationRate"),
        vec![
            val_f64("medNumberFood1"),
            val_f64("medNumberFood2"),
            val_f64("medNumberFood3"),
        ],
    )
}

/// Listing 2: one model execution with explicit parameters.
pub fn run(args: &Args) -> Result<Experiment> {
    let (evaluator, kind) = best_available_evaluator(1);
    let method = SingleRun {
        evaluator,
        kind: kind.to_string(),
        population: num(args.f64("population", 125.0))?,
        diffusion: num(args.f64("diffusion", 50.0))?,
        evaporation: num(args.f64("evaporation", 50.0))?,
        hooks: Vec::new(),
    };
    with_common(
        Experiment::new(Box::new(method)).env(env_spec(args, "local", 1)?),
        args,
    )
}

/// §Exploration: distributed design of experiments at calibration scale.
pub fn explore(args: &Args) -> Result<Experiment> {
    let n = num(args.usize("n", 1000))?;
    let chunk = num(args.usize("chunk", 256))?;
    let replications = num(args.usize("replications", 1))?;
    let nodes = num(args.usize("nodes", 8))?;
    let lo = num(args.f64("lo", 0.0))?;
    let hi = num(args.f64("hi", 99.0))?;
    let step = num(args.f64("step", 24.75))?;
    let out_path = args.get_or("out", "explore.csv").to_string();
    let format = match args.get("format") {
        Some("csv") => TableFormat::Csv,
        Some("jsonl") => TableFormat::Jsonl,
        Some(other) => {
            return Err(Error::Config(format!(
                "unknown --format `{other}` (csv|jsonl)"
            )))
        }
        None if out_path.ends_with(".jsonl") => TableFormat::Jsonl,
        None => TableFormat::Csv,
    };

    let (d, e, _) = genome_bounds();
    let sampling_name = args.get_or("sampling", "lhs").to_string();
    let sampling: Arc<dyn Sampling> = match sampling_name.as_str() {
        "lhs" => Arc::new(LhsSampling::new(&[(&d, lo, hi), (&e, lo, hi)], n)),
        "sobol" => {
            // validated here so an oversized design is a clean CLI error,
            // not the SobolSampling constructor's panic
            if n as u64 >= 1u64 << 32 {
                return Err(Error::Config(format!(
                    "--n {n} exceeds the Sobol sequence length (2^32 points)"
                )));
            }
            Arc::new(SobolSampling::new(&[(&d, lo, hi), (&e, lo, hi)], n))
        }
        "uniform" => Arc::new(UniformSampling::multi(&[(&d, lo, hi), (&e, lo, hi)], n)),
        "factorial" => {
            // validated here so a bad value is a clean CLI error, not the
            // Factor constructor's panic
            if !(step.is_finite() && step > 0.0) {
                return Err(Error::Config(format!(
                    "--step expects a positive finite number, got `{step}`"
                )));
            }
            let levels = (hi - lo) / step;
            if !levels.is_finite() || levels >= 1e6 {
                return Err(Error::Config(format!(
                    "--step {step} over [{lo}, {hi}] yields ~{levels:.0} levels \
                     per factor — refusing a grid this size"
                )));
            }
            Arc::new(FullFactorial::new(vec![
                Factor::new(&d, lo, hi, step),
                Factor::new(&e, lo, hi, step),
            ]))
        }
        other => {
            return Err(Error::Config(format!(
                "unknown --sampling `{other}` (lhs|sobol|uniform|factorial)"
            )))
        }
    };
    if sampling_name != "factorial" && !(lo.is_finite() && hi.is_finite() && lo < hi) {
        return Err(Error::Config(format!(
            "--lo must be below --hi (both finite) for --sampling \
             {sampling_name} (got lo={lo}, hi={hi})"
        )));
    }

    let (base, kind) = best_available_evaluator(2);
    let evaluator: Arc<dyn Evaluator> = if replications > 1 {
        Arc::new(ReplicatedEvaluator::new(base, replications))
    } else {
        base
    };
    let mut meta = vec![
        ("lo".to_string(), Json::Num(lo)),
        ("hi".to_string(), Json::Num(hi)),
        ("replications".to_string(), Json::Num(replications as f64)),
    ];
    if sampling_name == "factorial" {
        meta.push(("step".to_string(), Json::Num(step)));
    }
    // Out-of-core knobs. Deliberately NOT journaled as resume knobs: a
    // budget bounds memory, never the design, so a journal written under
    // any budget (or none) must resume under any other.
    let mem_budget = match args.get("mem-budget") {
        Some(s) => Some(parse_bytes("--mem-budget", s)?),
        None => None,
    };
    let spill_dir = args.get("spill-dir").map(str::to_string);
    let method = DirectSampling {
        sampling,
        evaluator,
        kind: kind.to_string(),
        design_columns: vec![d.name().to_string(), e.name().to_string()],
        objective_names: vec!["food1".into(), "food2".into(), "food3".into()],
        chunk,
        out_path,
        format,
        meta,
        degraded_ok: args.flag("degraded-ok"),
        retry_degraded: args.flag("retry-degraded"),
        mem_budget,
        spill_dir,
    };
    with_common(
        Experiment::new(Box::new(method)).env(env_spec(args, "local", nodes)?),
        args,
    )
}

/// Listing 3: replication + median through the workflow engine.
pub fn replicate(args: &Args) -> Result<Experiment> {
    let replications = num(args.usize("replications", 5))?;
    let nodes = num(args.usize("nodes", 4))?;
    let population = num(args.f64("population", 125.0))?;
    let diffusion = num(args.f64("diffusion", 50.0))?;
    let evaporation = num(args.f64("evaporation", 50.0))?;
    let (evaluator, kind) = best_available_evaluator(1);

    let seed_val = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let med = [
        val_f64("medNumberFood1"),
        val_f64("medNumberFood2"),
        val_f64("medNumberFood3"),
    ];
    let model = {
        let (seed_c, food_c) = (seed_val.clone(), food.clone());
        let ev = Arc::clone(&evaluator);
        ClosureTask::new("ants", move |ctx: &Context| {
            let s = ctx.get(&seed_c)?;
            let fit = ev.evaluate(&[population, diffusion, evaporation], s)?;
            let mut out = Context::new();
            for (f, v) in food_c.iter().zip(fit) {
                out.set(f, v);
            }
            Ok(out)
        })
        .input(&seed_val)
        .output(&food[0])
        .output(&food[1])
        .output(&food[2])
    };
    let mut stat = StatisticTask::new();
    for (f, m) in food.iter().zip(&med) {
        stat = stat.statistic(f, m, Descriptor::Median);
    }
    let method = Replication {
        model: Arc::new(model),
        seed_val,
        replications,
        statistic: Arc::new(stat),
        kind: kind.to_string(),
        model_hooks: vec![Arc::new(ToStringHook::new(&["food1", "food2", "food3"]))],
        statistic_hooks: vec![Arc::new(ToStringHook::new(&[
            "medNumberFood1",
            "medNumberFood2",
            "medNumberFood3",
        ]))],
    };
    with_common(
        Experiment::new(Box::new(method)).env(env_spec(args, "local", nodes)?),
        args,
    )
}

/// Listing 4: generational NSGA-II with replication-median fitness.
pub fn calibrate(args: &Args) -> Result<Experiment> {
    let mu = num(args.usize("mu", 10))?;
    let lambda = num(args.usize("lambda", 10))?;
    let generations = num(args.usize("generations", 100))? as u32;
    let replications = num(args.usize("replications", 5))?;
    let nodes = num(args.usize("nodes", 8))?;
    // --chunk N packs N genomes per evaluation job, fanned out through the
    // pooled batch path (§Perf): worthwhile on local/ssh environments
    let chunk = num(args.usize("chunk", 1))?;

    let (base, kind) = best_available_evaluator(2);
    let evaluator: Arc<dyn Evaluator> = if chunk > 1 {
        // chunked jobs carry whole batches. The evaluator gets its OWN
        // worker pool: environment workers block while a chunk fans out,
        // so sharing one pool could deadlock with every worker waiting
        Arc::new(PooledEvaluator::machine_sized(Arc::new(
            ReplicatedEvaluator::new(base, replications),
        )))
    } else {
        Arc::new(ReplicatedEvaluator::new(base, replications))
    };

    let (d, e, objectives) = genome_bounds();
    let obj_refs: Vec<&Val<f64>> = objectives.iter().collect();
    let config = Nsga2Config::new(
        mu,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &obj_refs,
        0.01,
    )?;
    let method = Nsga2Evolution {
        config,
        lambda,
        generations,
        eval_chunk: chunk,
        evaluator,
        kind: kind.to_string(),
        on_generation: Some(Arc::new(|g, pop| {
            let best: f64 = (0..pop.len())
                .map(|i| pop.objectives_row(i).iter().sum::<f64>())
                .fold(f64::INFINITY, f64::min);
            if g % 10 == 0 {
                println!("Generation {g}: best objective sum {best:.1}");
            }
        })),
    };
    with_common(
        Experiment::new(Box::new(method)).env(env_spec(args, "local", nodes)?),
        args,
    )
}

/// Listing 5 + §4.6: island NSGA-II on the (simulated) EGI.
pub fn island(args: &Args) -> Result<Experiment> {
    let mu = num(args.usize("mu", 200))?;
    let islands = num(args.usize("islands", 64))?;
    let total = num(args.u64("total-evals", 6400))?;
    let sample = num(args.usize("sample", 50))?;
    let per_island = num(args.u64("evals-per-island", 100))?;
    let nodes = num(args.usize("nodes", islands))?;
    let replications = num(args.usize("replications", 1))?;

    let (base, kind) = best_available_evaluator(2);
    let evaluator: Arc<dyn Evaluator> = if replications > 1 {
        Arc::new(ReplicatedEvaluator::new(base, replications))
    } else {
        base
    };
    let (d, e, objectives) = genome_bounds();
    let obj_refs: Vec<&Val<f64>> = objectives.iter().collect();
    let config = Nsga2Config::new(
        mu,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &obj_refs,
        0.01,
    )?;
    let method = IslandEvolution {
        config,
        islands: IslandConfig {
            concurrent_islands: islands,
            total_evaluations: total,
            island_sample: sample,
            evals_per_island: per_island,
        },
        evaluator,
        kind: kind.to_string(),
        on_island: Some(Arc::new(|done, evals| {
            if done % 16 == 0 {
                println!("Generation {done} islands merged, {evals} evaluations");
            }
        })),
    };
    with_common(
        Experiment::new(Box::new(method)).env(env_spec(args, "egi", nodes)?),
        args,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn explore_rejects_bad_knobs() {
        for (cmd, needle) in [
            ("explore --sampling warp", "unknown --sampling"),
            ("explore --format xml", "unknown --format"),
            ("explore --sampling factorial --step -1", "--step expects"),
            ("explore --sampling lhs --lo 5 --hi 1", "--lo must be below"),
            ("explore --seed notanumber", "expects an integer"),
            ("explore --mem-budget 12q", "expects BYTES"),
            ("explore --mem-budget 0", "positive byte count"),
        ] {
            let err = explore(&parse(cmd)).unwrap_err().to_string();
            assert!(err.contains(needle), "`{cmd}` → {err}");
        }
    }

    #[test]
    fn subcommand_fronts_build() {
        assert!(run(&parse("run")).is_ok());
        assert!(explore(&parse("explore --n 4")).is_ok());
        assert!(replicate(&parse("replicate")).is_ok());
        assert!(calibrate(&parse("calibrate")).is_ok());
        assert!(island(&parse("island")).is_ok());
    }

    #[test]
    fn mem_budget_parses_binary_suffixes() {
        assert_eq!(parse_bytes("--mem-budget", "64").unwrap(), 64);
        assert_eq!(parse_bytes("--mem-budget", "64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("--mem-budget", "2M").unwrap(), 2 << 20);
        assert_eq!(parse_bytes("--mem-budget", "1g").unwrap(), 1 << 30);
        for bad in ["", "12q", "0", "99999999999g"] {
            assert!(parse_bytes("--mem-budget", bad).is_err(), "`{bad}`");
        }
        // the out-of-core knobs reach the method and the front still builds
        assert!(explore(&parse(
            "explore --n 8 --sampling sobol --mem-budget 1m --spill-dir /tmp"
        ))
        .is_ok());
    }

    #[test]
    fn retry_flags_parse_and_reject_garbage() {
        assert!(retry_overrides(&parse("explore")).unwrap().is_none());
        let r = retry_overrides(&parse(
            "explore --timeout 120 --max-retries 2 --backoff 5",
        ))
        .unwrap()
        .expect("overrides present");
        assert_eq!(r.job_deadline_s, 120.0);
        assert_eq!(r.attempt_timeout_s, 120.0, "attempt timeout capped by deadline");
        assert_eq!(r.max_attempts, 3, "N retries = N+1 attempts");
        assert_eq!(r.backoff_base_s, 5.0);

        for (cmd, needle) in [
            ("explore --timeout -5 --n 4", "--timeout expects"),
            ("explore --backoff -1 --n 4", "--backoff expects"),
            ("explore --max-retries x --n 4", "expects an integer"),
        ] {
            let err = explore(&parse(cmd)).unwrap_err().to_string();
            assert!(err.contains(needle), "`{cmd}` → {err}");
        }
        // retry flags promote a single env to a one-backend brokered fleet
        assert!(explore(&parse("explore --n 4 --timeout 60")).is_ok());
    }

    #[test]
    fn provenance_argv_keeps_method_knobs_drops_env_and_persistence() {
        let args = parse(
            "explore --chunk 16 --n 64 --envs local:2 --policy least --seed 9 \
             --journal j.jsonl --out x.csv --durability always --spill-dir /tmp \
             --degraded-ok --speculate",
        );
        assert_eq!(
            provenance_argv(&args),
            vec!["--chunk", "16", "--n", "64", "--degraded-ok"],
            "env/persistence/seed/out are recorded structurally, not in argv"
        );
        // canonical: options sort by key regardless of invocation order
        assert_eq!(
            provenance_argv(&parse("explore --n 8 --chunk 4")),
            provenance_argv(&parse("explore --chunk 4 --n 8")),
        );
    }
}
