//! Lightweight metrics: counters, timers and throughput meters used by the
//! coordinator, environments and the bench harness.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Online timing statistics (count / total / min / max) in nanoseconds.
#[derive(Default)]
pub struct Timer {
    count: AtomicU64,
    total_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Timer {
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Time a closure and record its duration.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.total_ns.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            self.total() / c as u32
        }
    }

    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns.load(Ordering::Relaxed))
    }
}

/// Named registry for reporting.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn add(&self, name: &str, value: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += value;
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.counters.lock().unwrap().clone()
    }

    pub fn report(&self) -> String {
        self.snapshot()
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Evaluations-per-virtual-hour meter — the unit of the paper's headline
/// claim (200,000 individuals evaluated in one hour on EGI).
pub fn throughput_per_hour(completed: u64, virtual_makespan_s: f64) -> f64 {
    if virtual_makespan_s <= 0.0 {
        return 0.0;
    }
    completed as f64 * 3600.0 / virtual_makespan_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_records() {
        let t = Timer::default();
        t.record(Duration::from_millis(2));
        t.record(Duration::from_millis(4));
        assert_eq!(t.count(), 2);
        assert!(t.mean() >= Duration::from_millis(3));
        assert!(t.max() >= Duration::from_millis(4));
    }

    #[test]
    fn registry_reports() {
        let r = Registry::new();
        r.add("jobs", 2);
        r.add("jobs", 3);
        r.set("nodes", 7);
        assert_eq!(r.report(), "jobs=5 nodes=7");
    }

    #[test]
    fn throughput_math() {
        // 100 evals in 3600 virtual seconds = 100/hour
        assert_eq!(throughput_per_hour(100, 3600.0), 100.0);
        assert_eq!(throughput_per_hour(10, 0.0), 0.0);
    }
}
