//! MoleDSL v2 validation guarantees, through the public builder API: a
//! mis-typed or mis-wired puzzle is rejected by `build()`/`validate()`
//! before any job is submitted, with the offending capsule and variable
//! named.

use std::sync::Arc;

use molers::prelude::*;

fn err_of(b: &PuzzleBuilder) -> String {
    b.build().unwrap_err().to_string()
}

#[test]
fn missing_input_names_capsule_and_variable() {
    let x = val_f64("gDiffusionRate");
    let b = PuzzleBuilder::new();
    b.task(ClosureTask::new("ants", |_| Ok(Context::new())).input(&x));
    let err = err_of(&b);
    assert!(err.contains("`ants`"), "{err}");
    assert!(err.contains("`gDiffusionRate`"), "{err}");
    assert!(err.contains("not supplied"), "{err}");
}

#[test]
fn type_mismatch_names_both_types() {
    let n = val_f64("n");
    let n_str = val_str("n");
    let b = PuzzleBuilder::new();
    let producer = b.task(
        ClosureTask::new("producer", {
            let n = n.clone();
            move |_| Ok(Context::new().with(&n, 1.0))
        })
        .output(&n),
    );
    let consumer =
        b.task(ClosureTask::new("consumer", |_| Ok(Context::new())).input(&n_str));
    producer.then(&consumer);
    let err = err_of(&b);
    assert!(err.contains("`consumer`"), "{err}");
    assert!(err.contains("expects string"), "{err}");
    assert!(err.contains("supplies f64"), "{err}");
}

#[test]
fn aggregate_without_explore_is_rejected() {
    let b = PuzzleBuilder::new();
    let a = b.task(IdentityTask::new("model"));
    let c = b.task(IdentityTask::new("collect"));
    a.aggregate(&c);
    let err = err_of(&b);
    assert!(err.contains("no enclosing explore"), "{err}");
    assert!(err.contains("`model`"), "{err}");
}

#[test]
fn unreachable_capsule_is_rejected() {
    let b = PuzzleBuilder::new();
    let entry = b.task(IdentityTask::new("entry"));
    let next = b.task(IdentityTask::new("next"));
    let _orphan = b.task(IdentityTask::new("orphan"));
    entry.then(&next);
    let err = err_of(&b);
    assert!(err.contains("unreachable"), "{err}");
    assert!(err.contains("`orphan`"), "{err}");
}

#[test]
fn cycles_are_rejected_iteratively_even_on_deep_chains() {
    // 50k-deep chain with a back edge: the iterative traversal must
    // neither overflow the stack nor miss the cycle
    let b = PuzzleBuilder::new();
    let first = b.task(IdentityTask::new("c0"));
    let mut prev = first.clone();
    for i in 1..50_000 {
        let next = b.task(IdentityTask::new(format!("c{i}")));
        prev.then(&next);
        prev = next;
    }
    prev.then(&first); // the cycle
    let err = err_of(&b);
    assert!(err.contains("cycle"), "{err}");
}

#[test]
fn sampling_columns_satisfy_typed_inputs() {
    // the Listing 3 shape: a u32 seed column feeds a u32 model input,
    // and the aggregated outputs feed a statistic's list inputs
    let seed = val_u32("seed");
    let out = val_f64("out");
    let med = val_f64("med");
    let model = ClosureTask::new("model", {
        let (seed, out) = (seed.clone(), out.clone());
        move |ctx| Ok(Context::new().with(&out, f64::from(ctx.get(&seed)? % 3)))
    })
    .input(&seed)
    .output(&out);
    let stat = StatisticTask::new().statistic(&out, &med, Descriptor::Median);

    let b = PuzzleBuilder::new();
    replicate(&b, Arc::new(model), &seed, 4, Arc::new(stat));
    assert!(b.build().is_ok());
}

#[test]
fn aggregated_scalar_consumer_is_a_type_error() {
    // reading a replication's output as a scalar downstream of the
    // barrier is the classic OpenMOLE `toArray` mistake — caught at build
    let seed = val_u32("seed");
    let out = val_f64("out");
    let model = ClosureTask::new("model", {
        let (seed, out) = (seed.clone(), out.clone());
        move |ctx| Ok(Context::new().with(&out, f64::from(ctx.get(&seed)?)))
    })
    .input(&seed)
    .output(&out);
    // a scalar consumer where the statistic should be
    let scalar = ClosureTask::new("scalar", |_| Ok(Context::new())).input(&out);

    let b = PuzzleBuilder::new();
    replicate(&b, Arc::new(model), &seed, 4, Arc::new(scalar));
    let err = err_of(&b);
    assert!(err.contains("`scalar`"), "{err}");
    assert!(err.contains("expects f64"), "{err}");
    assert!(err.contains("list<f64>"), "{err}");
}

#[test]
fn validation_runs_before_any_job_is_submitted() {
    use std::sync::atomic::{AtomicBool, Ordering};
    static RAN: AtomicBool = AtomicBool::new(false);

    let x = val_f64("x");
    let puzzle = {
        let b2 = PuzzleBuilder::new();
        let bad2 = b2.task(
            ClosureTask::new("bad", |_| {
                RAN.store(true, Ordering::SeqCst);
                Ok(Context::new())
            })
            .input(&x),
        );
        let sink2 = b2.task(IdentityTask::new("sink"));
        bad2.then(&sink2);
        // build_with a context that satisfies x, then start WITHOUT it:
        // start_with must re-validate against the actual initial context
        b2.build_with(&Context::new().with(&x, 1.0)).unwrap()
    };
    let result = MoleExecution::new(puzzle, Arc::new(LocalEnvironment::new(1)), 1)
        .start();
    assert!(result.is_err(), "mis-wired start must fail");
    assert!(
        !RAN.load(Ordering::SeqCst),
        "no task may run before validation rejects the puzzle"
    );
}

#[test]
#[allow(deprecated)]
fn deprecated_puzzle_mutators_feed_the_same_validation() {
    // the v1 shims stay for one release; they must inherit v2 validation
    let x = val_f64("x");
    let mut p = Puzzle::new();
    let a = p.capsule(Arc::new(
        ClosureTask::new("producer", {
            let x = x.clone();
            move |_| Ok(Context::new().with(&x, 1.0))
        })
        .output(&x),
    ));
    let b = p.capsule(Arc::new(
        ClosureTask::new("consumer", |_| Ok(Context::new())).input(&val_str("x")),
    ));
    p.direct(a, b);
    let err = p.validate().unwrap_err().to_string();
    assert!(err.contains("expects string"), "{err}");
}
