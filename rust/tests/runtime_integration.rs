//! Integration: the PJRT-served JAX+Pallas model against the pure-Rust
//! twin, and the full three-layer stack under the GA. These tests skip
//! (pass vacuously, with a note) when `make artifacts` has not run.

use std::sync::Arc;

use molers::evolution::{
    AntSimEvaluator, Evaluator, GenerationalGA, Nsga2Config,
};
use molers::prelude::*;
use molers::runtime::{ArtifactManifest, PjrtEvaluator};
use molers::util::stats;

fn pjrt() -> Option<PjrtEvaluator> {
    if !ArtifactManifest::available() {
        eprintln!("artifacts missing; skipping pjrt integration test");
        return None;
    }
    Some(PjrtEvaluator::from_default_artifacts(1).unwrap())
}

#[test]
fn manifest_matches_python_settings() {
    let Some(ev) = pjrt() else { return };
    let m = ev.manifest();
    assert_eq!(m.world, 71);
    assert_eq!(m.max_ants, 200);
    assert_eq!(m.objectives.len(), 3);
    assert!(m.fitness_entries().count() >= 2, "single + batched artifacts");
}

#[test]
fn jax_and_rust_models_agree_distributionally() {
    // different RNGs, same dynamics: compare mean first-empty tick of the
    // near source over a seed ensemble (documented DESIGN.md §7 check)
    let Some(ev) = pjrt() else { return };
    let rust = AntSimEvaluator::new(); // same 1000-tick horizon as artifacts
    let genome = [50.0, 10.0];
    let n = 12;
    let jax_f1: Vec<f64> = (0..n)
        .map(|s| ev.evaluate(&genome, s).unwrap()[0])
        .collect();
    let rust_f1: Vec<f64> = (0..n)
        .map(|s| rust.evaluate(&genome, s).unwrap()[0])
        .collect();
    let (mj, mr) = (stats::mean(&jax_f1), stats::mean(&rust_f1));
    // both implementations resolve the near source well before the horizon
    assert!(mj < 900.0, "jax model never forages: {mj}");
    assert!(mr < 900.0, "rust model never forages: {mr}");
    // means within a factor 2 of each other (sequential-vs-synchronous ask)
    let ratio = mj.max(mr) / mj.min(mr);
    assert!(
        ratio < 2.0,
        "jax ({mj:.0}) and rust ({mr:.0}) disagree beyond tolerance"
    );
}

#[test]
fn near_source_empties_first_in_both_backends() {
    let Some(ev) = pjrt() else { return };
    let rust = AntSimEvaluator::new();
    for seed in 0..6u32 {
        for fit in [
            ev.evaluate(&[50.0, 10.0], seed).unwrap(),
            rust.evaluate(&[50.0, 10.0], seed).unwrap(),
        ] {
            assert!(
                fit[0] <= fit[2],
                "near source must empty no later than far (seed {seed}): {fit:?}"
            );
        }
    }
}

#[test]
fn full_stack_ga_over_pjrt() {
    // the production configuration: NSGA-II driving the Pallas/JAX/PJRT
    // model through the workflow evaluation task
    let Some(ev) = pjrt() else { return };
    let d = val_f64("gDiffusionRate");
    let e = val_f64("gEvaporationRate");
    let m1 = val_f64("med1");
    let m2 = val_f64("med2");
    let m3 = val_f64("med3");
    let config = Nsga2Config::new(
        6,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &[&m1, &m2, &m3],
        0.0,
    )
    .unwrap();
    let env = LocalEnvironment::new(2);
    let ga = GenerationalGA::new(config, Arc::new(ev), 6);
    let result = ga.run(&env, 3, 1).unwrap();
    assert_eq!(result.evaluations, 6 * 4);
    assert!(!result.pareto_front.is_empty());
    for ind in &result.pareto_front {
        assert!(ind.objectives.iter().all(|&o| (1.0..=1000.0).contains(&o)));
    }
}

#[test]
fn evaluator_is_shareable_across_threads() {
    let Some(ev) = pjrt() else { return };
    let ev = Arc::new(ev);
    let want = ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let ev = Arc::clone(&ev);
            let want = want.clone();
            std::thread::spawn(move || {
                for _ in 0..3 {
                    assert_eq!(ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap(), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
