//! End-to-end CLI tests: drive the `molers` launcher binary the way a
//! user would (paper §4's A-to-Z flow at smoke scale).

use std::process::Command;

fn molers() -> Command {
    Command::new(env!("CARGO_BIN_EXE_molers"))
}

#[test]
fn envs_lists_all_environments() {
    let out = molers().arg("envs").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for env in ["local", "ssh", "pbs", "slurm", "sge", "oar", "condor", "egi"] {
        assert!(text.contains(env), "missing env `{env}` in listing");
    }
}

#[test]
fn no_subcommand_prints_usage() {
    let out = molers().output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage: molers"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = molers().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn render_writes_ppm() {
    let path = std::env::temp_dir().join(format!("molers-cli-{}.ppm", std::process::id()));
    let out = molers()
        .args(["render", "--ticks", "60", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.starts_with(b"P6\n"), "not a PPM file");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn render_ascii_shows_world() {
    let out = molers().args(["render", "--ticks", "30"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains('N'), "nest missing from ascii render");
    assert!(text.contains("remaining food per source"));
}

#[test]
fn run_falls_back_without_artifacts() {
    // point the runtime at an empty artifact dir: the rust-sim twin takes over
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["run", "--seed", "7", "--evaporation", "10"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("evaluator: rust-sim"));
    assert!(text.contains("final-ticks-food1="));
}

#[test]
fn explore_sweeps_and_writes_incremental_csv() {
    let dir = std::env::temp_dir();
    let csv = dir.join(format!("molers-cli-explore-{}.csv", std::process::id()));
    let journal = dir.join(format!("molers-cli-explore-{}.jsonl", std::process::id()));
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--sampling", "sobol", "--n", "12", "--chunk", "5"])
        .args(["--envs", "local:2,local:2~0.3", "--seed", "9"])
        .arg("--out")
        .arg(&csv)
        .arg("--journal")
        .arg(&journal)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sampling: Sobol (12 rows"), "stdout: {text}");
    assert!(text.contains("rows=12 evaluated=12 resumed=0"), "stdout: {text}");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), 13, "header + 12 rows");
    assert!(csv_text.starts_with("gDiffusionRate,gEvaporationRate,food1,food2,food3\n"));
    let journal_text = std::fs::read_to_string(&journal).unwrap();
    assert!(journal_text.contains("\"kind\":\"sample_block\""));
    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn explore_resume_rejects_mismatched_seed() {
    let dir = std::env::temp_dir();
    let journal = dir.join(format!("molers-cli-exmis-{}.jsonl", std::process::id()));
    std::fs::write(
        &journal,
        "{\"kind\":\"run_start\",\"run\":\"explore\",\"seed\":1,\"sampling\":\"LHS\",\"n\":4,\"chunk\":2,\"resumed_rows\":0}\n",
    )
    .unwrap();
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--n", "4", "--seed", "2", "--resume"])
        .arg(&journal)
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("config mismatch"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn unknown_env_is_a_hard_error_listing_valid_names() {
    // a typo'd --env must NOT quietly run the campaign on the laptop
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--n", "4", "--env", "slrum"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown environment `slrum`"), "{err}");
    assert!(err.contains("slurm"), "lists the valid names: {err}");
}

#[test]
fn bad_option_value_is_a_clean_error() {
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["run", "--seed", "notanumber"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("expects an integer"));
}
