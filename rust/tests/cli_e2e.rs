//! End-to-end CLI tests: drive the `molers` launcher binary the way a
//! user would (paper §4's A-to-Z flow at smoke scale).

use std::process::Command;

fn molers() -> Command {
    Command::new(env!("CARGO_BIN_EXE_molers"))
}

#[test]
fn envs_lists_all_environments() {
    let out = molers().arg("envs").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for env in ["local", "ssh", "pbs", "slurm", "sge", "oar", "condor", "egi"] {
        assert!(text.contains(env), "missing env `{env}` in listing");
    }
}

#[test]
fn no_subcommand_prints_usage() {
    let out = molers().output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage: molers"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = molers().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn render_writes_ppm() {
    let path = std::env::temp_dir().join(format!("molers-cli-{}.ppm", std::process::id()));
    let out = molers()
        .args(["render", "--ticks", "60", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.starts_with(b"P6\n"), "not a PPM file");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn render_ascii_shows_world() {
    let out = molers().args(["render", "--ticks", "30"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains('N'), "nest missing from ascii render");
    assert!(text.contains("remaining food per source"));
}

#[test]
fn run_falls_back_without_artifacts() {
    // point the runtime at an empty artifact dir: the rust-sim twin takes over
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["run", "--seed", "7", "--evaporation", "10"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("evaluator: rust-sim"));
    assert!(text.contains("final-ticks-food1="));
}

#[test]
fn explore_sweeps_and_writes_incremental_csv() {
    let dir = std::env::temp_dir();
    let csv = dir.join(format!("molers-cli-explore-{}.csv", std::process::id()));
    let journal = dir.join(format!("molers-cli-explore-{}.jsonl", std::process::id()));
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--sampling", "sobol", "--n", "12", "--chunk", "5"])
        .args(["--envs", "local:2,local:2~0.3", "--seed", "9"])
        .arg("--out")
        .arg(&csv)
        .arg("--journal")
        .arg(&journal)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sampling: Sobol (12 rows"), "stdout: {text}");
    assert!(text.contains("rows=12 evaluated=12 resumed=0"), "stdout: {text}");
    let csv_text = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(csv_text.lines().count(), 13, "header + 12 rows");
    assert!(csv_text.starts_with("gDiffusionRate,gEvaporationRate,food1,food2,food3\n"));
    let journal_text = std::fs::read_to_string(&journal).unwrap();
    assert!(journal_text.contains("\"kind\":\"sample_block\""));
    let _ = std::fs::remove_file(&csv);
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn explore_resume_rejects_mismatched_seed() {
    let dir = std::env::temp_dir();
    let journal = dir.join(format!("molers-cli-exmis-{}.jsonl", std::process::id()));
    std::fs::write(
        &journal,
        "{\"kind\":\"run_start\",\"run\":\"explore\",\"seed\":1,\"sampling\":\"LHS\",\"n\":4,\"chunk\":2,\"resumed_rows\":0}\n",
    )
    .unwrap();
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--n", "4", "--seed", "2", "--resume"])
        .arg(&journal)
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("config mismatch"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn unknown_env_is_a_hard_error_listing_valid_names() {
    // a typo'd --env must NOT quietly run the campaign on the laptop
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["explore", "--n", "4", "--env", "slrum"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown environment `slrum`"), "{err}");
    assert!(err.contains("slurm"), "lists the valid names: {err}");
}

/// Kill the daemon on drop so a failing assertion never leaks it.
struct KillOnDrop(std::process::Child);

impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

#[test]
fn client_round_trips_against_a_live_daemon() {
    let dir = std::env::temp_dir().join(format!("molers-cli-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut daemon = KillOnDrop(
        molers()
            .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
            .env("MOLERS_SIM_TICKS", "40")
            .args(["serve", "--addr", "127.0.0.1:0", "--envs", "local:2", "--state-dir"])
            .arg(&dir)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .spawn()
            .unwrap(),
    );
    // ephemeral port: discover the bound address from the state dir
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    let addr = loop {
        if let Ok(text) = std::fs::read_to_string(dir.join("addr")) {
            let addr = text.trim().to_string();
            if !addr.is_empty() && std::net::TcpStream::connect(&addr).is_ok() {
                break addr;
            }
        }
        assert!(std::time::Instant::now() < deadline, "daemon never came up");
        std::thread::sleep(std::time::Duration::from_millis(20));
    };
    let client = |args: &[&str]| {
        molers()
            .args(["client"])
            .args(args)
            .args(["--addr", &addr])
            .output()
            .unwrap()
    };

    let out = client(&["ping"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("\"pong\":true"));

    let out = client(&[
        "submit", "explore", "--n", "8", "--chunk", "4", "--tenant", "alice",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("\"ok\":true") && text.contains("\"id\":1"), "{text}");

    // watch streams state events and exits when the run lands
    let out = client(&["watch", "--id", "1"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("\"state\":\"done\""), "{text}");

    let out = client(&["status", "--id", "1"]);
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("\"state\":\"done\""), "{text}");
    assert!(text.contains("\"tenant\":\"alice\""), "{text}");

    // the sweep's CSV comes back over the wire
    let out = client(&["result", "--id", "1"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(
        String::from_utf8_lossy(&out.stdout).contains("gDiffusionRate"),
        "result payload missing the design columns"
    );

    // server-side errors surface as a non-zero client exit
    let out = client(&["status", "--id", "99"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown experiment"));

    let out = client(&["shutdown"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let status = daemon.0.wait().unwrap();
    assert!(status.success(), "shutdown exits the daemon cleanly");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn client_without_a_daemon_is_a_clean_error() {
    let out = molers()
        // a port from the ephemeral range nothing is listening on
        .args(["client", "ping", "--addr", "127.0.0.1:1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("cannot connect to molers serve"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn bad_option_value_is_a_clean_error() {
    let out = molers()
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .args(["run", "--seed", "notanumber"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("expects an integer"));
}
