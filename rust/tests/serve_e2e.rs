//! End-to-end tests for `molers serve`: drive the real daemon binary
//! over TCP the way a client would — concurrent multi-tenant sweeps,
//! admission control, cancellation, kill -9 + restart resume.
//!
//! Every test uses `MOLERS_ARTIFACTS=/nonexistent-artifacts` (force the
//! deterministic rust-sim evaluator) and `MOLERS_SIM_TICKS` (cut the
//! per-evaluation cost so debug-mode CI stays fast). Each test gets its
//! own state dir + an ephemeral port discovered via `<dir>/addr`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use molers::util::json::{self, Json};

const SIM_TICKS: &str = "40";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("molers-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A running daemon; killed on drop so a failing test never leaks it.
struct Daemon {
    child: Child,
    addr: String,
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `molers serve` on an ephemeral port and wait until it accepts.
fn start_server(dir: &Path, extra: &[&str]) -> Daemon {
    let addr_file = dir.join("addr");
    let _ = std::fs::remove_file(&addr_file);
    let child = Command::new(env!("CARGO_BIN_EXE_molers"))
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .env("MOLERS_SIM_TICKS", SIM_TICKS)
        .args(["serve", "--addr", "127.0.0.1:0", "--state-dir"])
        .arg(dir)
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn molers serve");
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Ok(text) = std::fs::read_to_string(&addr_file) {
            let addr = text.trim().to_string();
            if !addr.is_empty() && TcpStream::connect(&addr).is_ok() {
                break addr;
            }
        }
        assert!(Instant::now() < deadline, "daemon never came up");
        std::thread::sleep(Duration::from_millis(20));
    };
    Daemon { child, addr }
}

/// One request line → one response line, parsed.
fn request(addr: &str, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).expect("connect");
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    json::parse(resp.trim_end()).unwrap_or_else(|e| panic!("bad response `{resp}`: {e}"))
}

fn submit(addr: &str, run: &str, tenant: &str, options: &[(&str, &str)]) -> u64 {
    let opts: String = options
        .iter()
        .map(|(k, v)| format!("\"{k}\":\"{v}\""))
        .collect::<Vec<_>>()
        .join(",");
    let resp = request(
        addr,
        &format!(
            "{{\"cmd\":\"submit\",\"run\":\"{run}\",\"tenant\":\"{tenant}\",\
             \"options\":{{{opts}}}}}"
        ),
    );
    assert_eq!(
        resp.get("ok"),
        Some(&Json::Bool(true)),
        "submit rejected: {resp}"
    );
    resp.get("id").and_then(Json::as_f64).expect("id") as u64
}

fn status(addr: &str, id: u64) -> Json {
    request(addr, &format!("{{\"cmd\":\"status\",\"id\":{id}}}"))
}

fn state_of(status: &Json) -> String {
    status
        .get("state")
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string()
}

fn is_terminal(state: &str) -> bool {
    matches!(state, "done" | "degraded" | "failed" | "cancelled")
}

/// Poll until the experiment reaches a terminal state; returns the final
/// status object.
fn wait_terminal(addr: &str, id: u64, timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let s = status(addr, id);
        if is_terminal(&state_of(&s)) {
            return s;
        }
        assert!(
            Instant::now() < deadline,
            "experiment {id} never finished: {s}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn fair_share_lets_a_small_calibrate_finish_under_a_big_sweep() {
    let dir = tmp_dir("fair");
    let daemon = start_server(&dir, &["--envs", "local:2", "--max-running", "2"]);
    let addr = &daemon.addr;

    // the hog: a 240-row sweep in 2-row chunks floods the gate with 120
    // pending jobs...
    let big = submit(
        addr,
        "explore",
        "hog",
        &[("n", "240"), ("chunk", "2"), ("sampling", "sobol"), ("seed", "9")],
    );
    // ...then a small calibration arrives late on another tenant
    let small = submit(
        addr,
        "calibrate",
        "quick",
        &[
            ("mu", "4"),
            ("lambda", "4"),
            ("generations", "2"),
            ("replications", "1"),
        ],
    );

    let small_status = wait_terminal(addr, small, Duration::from_secs(120));
    assert_eq!(state_of(&small_status), "done", "{small_status}");
    // the fair gate's whole point: the small tenant finished while the
    // hog's sweep was still in flight (FIFO job order would have parked
    // every calibrate job behind the 120 queued sweep chunks)
    let big_now = state_of(&status(addr, big));
    assert!(
        !is_terminal(&big_now),
        "the 240-row sweep (state `{big_now}`) finished before the \
         4-genome calibrate — fair share is not interleaving tenants"
    );
    assert_eq!(
        small_status.get("history"),
        Some(&Json::Arr(vec![
            Json::Str("queued".into()),
            Json::Str("running".into()),
            Json::Str("done".into()),
        ])),
    );
    // satellite: fleet health (timeouts + injected faults) on `status`
    let fleet = small_status.get("fleet").expect("fleet stats");
    assert!(fleet.get("timed_out_attempts").is_some(), "{small_status}");
    assert!(fleet.get("injected_faults").is_some(), "{small_status}");

    let big_status = wait_terminal(addr, big, Duration::from_secs(120));
    assert_eq!(state_of(&big_status), "done", "{big_status}");
    assert!(dir.join(format!("exp-{big}.csv")).exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn admission_control_rejects_when_saturated_and_cancel_frees_the_queue() {
    let dir = tmp_dir("admission");
    let daemon = start_server(
        &dir,
        &["--envs", "local:1", "--max-running", "1", "--max-queued", "1"],
    );
    let addr = &daemon.addr;

    let running = submit(addr, "explore", "a", &[("n", "400"), ("chunk", "2")]);
    // give the scheduler a beat to move #1 from the queue into running so
    // #2 occupies the single queue slot
    let deadline = Instant::now() + Duration::from_secs(30);
    while state_of(&status(addr, running)) == "queued" {
        assert!(Instant::now() < deadline, "experiment 1 never started");
        std::thread::sleep(Duration::from_millis(10));
    }
    let queued = submit(addr, "explore", "b", &[("n", "8"), ("chunk", "4")]);

    let resp = request(
        addr,
        "{\"cmd\":\"submit\",\"run\":\"explore\",\"options\":{\"n\":\"8\"}}",
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp}");
    let msg = resp.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("server saturated"), "{resp}");

    // a bad submission is rejected with the CLI front's own error and
    // allocates no id even under saturation
    let resp = request(
        addr,
        "{\"cmd\":\"submit\",\"run\":\"explore\",\"options\":{\"sampling\":\"warp\"}}",
    );
    let msg = resp.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("unknown --sampling"), "{resp}");

    // cancelling the queued experiment frees the slot immediately
    let resp = request(addr, &format!("{{\"cmd\":\"cancel\",\"id\":{queued}}}"));
    assert_eq!(resp.get("state"), Some(&Json::Str("cancelled".into())), "{resp}");
    submit(addr, "explore", "c", &[("n", "8"), ("chunk", "4")]);

    // cancelling the running one makes its queued fair-share jobs fail
    // fast; the experiment lands in `cancelled`, not `failed`
    let resp = request(addr, &format!("{{\"cmd\":\"cancel\",\"id\":{running}}}"));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    let s = wait_terminal(addr, running, Duration::from_secs(120));
    assert_eq!(state_of(&s), "cancelled", "{s}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_dash_nine_then_restart_resumes_to_a_byte_identical_result() {
    let dir = tmp_dir("kill");
    std::fs::create_dir_all(&dir).unwrap();
    let ref_csv = dir.join("reference.csv");
    let sweep: &[(&str, &str)] = &[
        ("n", "120"),
        ("chunk", "4"),
        ("sampling", "sobol"),
        ("seed", "9"),
    ];

    // reference: the same sweep through the plain CLI, same fleet shape
    let out = Command::new(env!("CARGO_BIN_EXE_molers"))
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .env("MOLERS_SIM_TICKS", SIM_TICKS)
        .args(["explore", "--envs", "local:2", "--out"])
        .arg(&ref_csv)
        .args(sweep.iter().flat_map(|(k, v)| [format!("--{k}"), v.to_string()]))
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let reference = std::fs::read(&ref_csv).unwrap();

    // served run: SIGKILL the daemon once the first checkpoint lands
    let state = tmp_dir("kill-state");
    let mut daemon = start_server(&state, &["--envs", "local:2"]);
    let id = submit(&daemon.addr, "explore", "alice", sweep);
    let journal = state.join(format!("exp-{id}.jsonl"));
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if std::fs::read_to_string(&journal)
            .map(|t| t.contains("\"kind\":\"sample_block\""))
            .unwrap_or(false)
        {
            break;
        }
        assert!(Instant::now() < deadline, "no checkpoint ever appeared");
        std::thread::sleep(Duration::from_millis(5));
    }
    daemon.child.kill().unwrap();
    let _ = daemon.child.wait();
    drop(daemon);

    // restart on the same state dir: the unfinished experiment is
    // re-enqueued and resumes from its own journal
    let daemon = start_server(&state, &["--envs", "local:2"]);
    let s = wait_terminal(&daemon.addr, id, Duration::from_secs(120));
    assert_eq!(state_of(&s), "done", "{s}");
    assert_eq!(s.get("restored"), Some(&Json::Bool(true)), "{s}");
    let served = std::fs::read(state.join(format!("exp-{id}.csv"))).unwrap();
    assert_eq!(
        served, reference,
        "resumed result file differs from the uninterrupted reference run"
    );
    // `result` serves the same bytes over the wire
    let resp = request(&daemon.addr, &format!("{{\"cmd\":\"result\",\"id\":{id}}}"));
    assert_eq!(
        resp.get("content").and_then(Json::as_str),
        Some(String::from_utf8(reference).unwrap().as_str())
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn concurrent_experiments_never_share_a_journal() {
    let dir = tmp_dir("journals");
    let daemon = start_server(&dir, &["--envs", "local:2", "--max-running", "2"]);
    let addr = &daemon.addr;
    let a = submit(
        addr,
        "explore",
        "alice",
        &[("n", "40"), ("chunk", "4"), ("seed", "7")],
    );
    let b = submit(
        addr,
        "explore",
        "bob",
        &[("n", "40"), ("chunk", "4"), ("seed", "8")],
    );
    assert_eq!(state_of(&wait_terminal(addr, a, Duration::from_secs(120))), "done");
    assert_eq!(state_of(&wait_terminal(addr, b, Duration::from_secs(120))), "done");

    // each experiment owns exactly one journal, keyed by id, and each
    // parses cleanly with its OWN run header — two concurrent sweeps
    // under one server dir never interleaved records
    for (id, seed) in [(a, "7"), (b, "8")] {
        let records =
            molers::broker::Journal::load(dir.join(format!("exp-{id}.jsonl"))).unwrap();
        let starts: Vec<&Json> = records
            .iter()
            .filter(|r| r.get("kind").and_then(Json::as_str) == Some("run_start"))
            .collect();
        assert_eq!(starts.len(), 1, "exp-{id}: one run_start");
        assert_eq!(
            starts[0].get("seed_exact").and_then(Json::as_str),
            Some(seed),
            "exp-{id} journaled another experiment's seed"
        );
        assert_eq!(
            records
                .iter()
                .filter(|r| r.get("kind").and_then(Json::as_str) == Some("run_end"))
                .count(),
            1,
            "exp-{id}: one run_end"
        );
    }
    // the server meta-journal has both submissions and both terminal states
    let meta = molers::broker::Journal::load(dir.join("server.jsonl")).unwrap();
    let kinds = |k: &str| {
        meta.iter()
            .filter(|r| r.get("kind").and_then(Json::as_str) == Some(k))
            .count()
    };
    assert_eq!(kinds("exp"), 2);
    assert_eq!(kinds("exp_state"), 2);
    let _ = std::fs::remove_dir_all(&dir);
}
