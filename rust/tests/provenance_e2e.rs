//! End-to-end provenance: run a real experiment, emit its manifest, and
//! prove `reexec` reproduces the result byte-for-byte from the manifest
//! alone — then prove every way the chain can break is a *named*
//! provenance error, never a silent success.
//!
//! All tests pin `MOLERS_ARTIFACTS=/nonexistent-artifacts` (deterministic
//! rust-sim evaluator) and a small `MOLERS_SIM_TICKS`, exactly like the
//! serve e2e suite.

use std::path::PathBuf;

use molers::cli::{front, Args};
use molers::provenance;

fn pin_env() {
    std::env::set_var("MOLERS_ARTIFACTS", "/nonexistent-artifacts");
    std::env::set_var("MOLERS_SIM_TICKS", "6");
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("molers-prov-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn parse(argv: &[&str]) -> Args {
    Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
}

/// Run an explore sweep to `out` and emit its manifest; returns the
/// manifest path.
fn explore_with_manifest(out: &std::path::Path, seed: &str) -> String {
    let args = parse(&[
        "explore",
        "--n",
        "48",
        "--chunk",
        "16",
        "--seed",
        seed,
        "--out",
        out.to_str().unwrap(),
    ]);
    let exp = front::by_name("explore", &args).unwrap().quiet();
    let report = exp.run().unwrap();
    let result_path = report.outcome.result_path.clone().expect("explore writes --out");
    provenance::emit_for_cli("explore", &args, &exp, &result_path)
        .unwrap()
        .expect("concrete env spec → manifest")
}

#[test]
fn explore_reexec_is_byte_identical_without_original_artifacts() {
    pin_env();
    let dir = tmp_dir("roundtrip");
    let out = dir.join("sweep.csv");
    let manifest = explore_with_manifest(&out, "11");

    // the manifest is enough: delete the original result AND never hand
    // reexec a journal — the digest assertion still has the recorded hash
    let recorded = std::fs::read(&out).unwrap();
    std::fs::remove_file(&out).unwrap();
    let rep = provenance::reexec(&manifest, &parse(&["reexec", &manifest])).unwrap();
    assert_eq!(rep.run, "explore");
    assert!(rep.evaluations >= 48, "{}", rep.evaluations);
    assert!(rep.regenerated.is_none(), "scratch file is cleaned up");

    // --out keeps the regenerated file, byte-identical to the original
    let kept = dir.join("regen.csv");
    let rx = parse(&["reexec", &manifest, "--out", kept.to_str().unwrap()]);
    let rep = provenance::reexec(&manifest, &rx).unwrap();
    assert_eq!(rep.regenerated.as_deref(), Some(kept.as_path()));
    assert_eq!(std::fs::read(&kept).unwrap(), recorded);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tampered_result_is_a_named_error() {
    pin_env();
    let dir = tmp_dir("tamper");
    let out = dir.join("sweep.csv");
    let manifest = explore_with_manifest(&out, "13");

    let mut bytes = std::fs::read(&out).unwrap();
    bytes.extend_from_slice(b"# one extra row\n");
    std::fs::write(&out, bytes).unwrap();

    let err = provenance::reexec(&manifest, &parse(&["reexec", &manifest]))
        .unwrap_err()
        .to_string();
    assert!(err.starts_with("provenance error [result-tampered]"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn env_override_mismatch_is_named_and_ignorable() {
    pin_env();
    let dir = tmp_dir("envovr");
    let out = dir.join("sweep.csv");
    let manifest = explore_with_manifest(&out, "17");

    // a different fleet than the record → named refusal
    let rx = parse(&["reexec", &manifest, "--envs", "local:2,local:2"]);
    let err = provenance::reexec(&manifest, &rx).unwrap_err().to_string();
    assert!(err.starts_with("provenance error [env-fleet-mismatch]"), "{err}");

    // --ignore-compat downgrades the refusal; the run still happens on
    // the *recorded* fleet, so the digest assertion passes
    let rx = parse(&[
        "reexec",
        &manifest,
        "--envs",
        "local:2,local:2",
        "--ignore-compat",
    ]);
    provenance::reexec(&manifest, &rx).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn patched_build_record_is_a_named_error() {
    pin_env();
    let dir = tmp_dir("build");
    let out = dir.join("sweep.csv");
    let manifest = explore_with_manifest(&out, "19");

    let text = std::fs::read_to_string(&manifest).unwrap();
    let ours = format!("\"crate_version\":\"{}\"", env!("CARGO_PKG_VERSION"));
    assert!(text.contains(&ours), "{text}");
    std::fs::write(
        &manifest,
        text.replace(&ours, "\"crate_version\":\"0.0.0-elsewhere\""),
    )
    .unwrap();

    let err = provenance::reexec(&manifest, &parse(&["reexec", &manifest]))
        .unwrap_err()
        .to_string();
    assert!(err.starts_with("provenance error [build-mismatch]"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn calibrate_front_reexec_roundtrip() {
    pin_env();
    let dir = tmp_dir("calibrate");
    let args = parse(&[
        "calibrate",
        "--mu",
        "6",
        "--lambda",
        "6",
        "--generations",
        "2",
        "--replications",
        "1",
        "--seed",
        "23",
    ]);
    let exp = front::by_name("calibrate", &args).unwrap().quiet();
    let report = exp.run().unwrap();
    assert!(!report.outcome.pareto_front.is_empty());

    // the CLI writes the durable front file, then the manifest over it
    let front_path = dir.join("front.jsonl");
    provenance::write_front_file(&front_path, &report.outcome.pareto_front).unwrap();
    let manifest = provenance::emit_for_cli(
        "calibrate",
        &args,
        &exp,
        front_path.to_str().unwrap(),
    )
    .unwrap()
    .expect("concrete env spec → manifest");

    let rep = provenance::reexec(&manifest, &parse(&["reexec", &manifest])).unwrap();
    assert_eq!(rep.run, "calibrate");
    assert!(rep.evaluations > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn garbage_manifest_is_named_malformed() {
    pin_env();
    let dir = tmp_dir("malformed");
    let path = dir.join("x.manifest.json");
    std::fs::write(&path, "{\"kind\":\"something-else\"}").unwrap();
    let p = path.to_str().unwrap().to_string();
    let err = provenance::reexec(&p, &parse(&["reexec", &p]))
        .unwrap_err()
        .to_string();
    assert!(err.starts_with("provenance error [manifest-malformed]"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
