//! Integration: evolution drivers against the real ant model (Rust twin)
//! and the simulated environments — the paper's §4.5/§4.6 claims in shape.

use std::sync::Arc;

use molers::environment::egi::EgiEnvironment;
use molers::evolution::{
    AntSimEvaluator, CountingEvaluator, Evaluator, GenerationalGA, IslandConfig,
    IslandSteadyGA, Nsga2Config, ReplicatedEvaluator, SteadyStateGA, Termination,
    Zdt1Evaluator,
};
use molers::exec::ThreadPool;
use molers::prelude::*;

fn ant_config(mu: usize) -> Nsga2Config {
    let d = val_f64("gDiffusionRate");
    let e = val_f64("gEvaporationRate");
    let m1 = val_f64("med1");
    let m2 = val_f64("med2");
    let m3 = val_f64("med3");
    Nsga2Config::new(mu, &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)], &[&m1, &m2, &m3], 0.01)
        .unwrap()
}

#[test]
fn calibration_improves_ant_foraging() {
    // Listing 4 scaled down: the GA must find parameters that forage
    // dramatically better than the paper's (50, 50) defaults
    let evaluator = Arc::new(AntSimEvaluator::fast());
    let default_fit: f64 = evaluator
        .evaluate(&[50.0, 50.0], 11)
        .unwrap()
        .iter()
        .sum();
    let env = LocalEnvironment::new(4);
    let ga = GenerationalGA::new(ant_config(8), evaluator, 8);
    let result = ga.run(&env, 8, 42).unwrap();
    let best: f64 = result
        .population
        .iter()
        .map(|i| i.objectives.iter().sum::<f64>())
        .fold(f64::INFINITY, f64::min);
    assert!(
        best < default_fit,
        "calibration ({best}) should beat defaults ({default_fit})"
    );
    // calibrated solutions lean on persistent trails: low evaporation
    let front_best = result
        .pareto_front
        .iter()
        .min_by(|a, b| {
            a.objectives
                .iter()
                .sum::<f64>()
                .partial_cmp(&b.objectives.iter().sum::<f64>())
                .unwrap()
        })
        .unwrap();
    assert!(
        front_best.genome[1] < 50.0,
        "best evaporation-rate should be below the default: {:?}",
        front_best.genome
    );
}

#[test]
fn replicated_fitness_is_more_stable_generationally() {
    // §4.4's rationale inside the GA: median-of-5 fitness varies less
    // between reevaluations than single-draw fitness
    let base = Arc::new(AntSimEvaluator::fast());
    let single = Arc::clone(&base) as Arc<dyn Evaluator>;
    let replicated: Arc<dyn Evaluator> =
        Arc::new(ReplicatedEvaluator::new(Arc::clone(&base) as _, 5));
    let genome = [60.0, 12.0];
    let spread = |ev: &Arc<dyn Evaluator>| -> f64 {
        let fits: Vec<f64> = (0..8)
            .map(|s| ev.evaluate(&genome, s).unwrap()[0])
            .collect();
        let max = fits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = fits.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    };
    assert!(
        spread(&replicated) <= spread(&single),
        "replication must not widen the fitness spread"
    );
}

#[test]
fn island_model_runs_ant_calibration_on_egi() {
    // Listing 5 scaled down, with REAL ant evaluations inside the islands
    let pool = Arc::new(ThreadPool::new(4));
    let env = EgiEnvironment::new("biomed", 8, pool, 5);
    let counting = Arc::new(CountingEvaluator::new(AntSimEvaluator::fast()));
    let ga = IslandSteadyGA::new(
        ant_config(20),
        IslandConfig {
            concurrent_islands: 8,
            total_evaluations: 160,
            island_sample: 10,
            evals_per_island: 20,
        },
        Arc::clone(&counting) as _,
    );
    let result = ga.run(&env, 42, None).unwrap();
    assert_eq!(result.evaluations, 160);
    assert_eq!(counting.count(), 160);
    assert_eq!(result.generations, 8, "8 islands of 20 evals");
    assert!(!result.pareto_front.is_empty());
    // virtual time: each island ~20 evals x 9 s nominal on heterogeneous
    // nodes, 8 concurrent -> makespan far below the serial 8x
    let serial = 160.0 * 9.0;
    assert!(
        result.virtual_makespan < serial,
        "islands must overlap in virtual time: {} vs serial {serial}",
        result.virtual_makespan
    );
}

#[test]
fn islands_beat_per_evaluation_delegation_on_grid() {
    // §4.6's actual claim: "Islands are better suited to exploit
    // distributed computing resources than classical generational genetic
    // algorithms." The mechanism: an island is ONE grid job bundling many
    // evaluations, so grid brokering latency (~minutes on EGI) is paid
    // once per island rather than once per evaluation, and there is no
    // global generation barrier. Same budget, same grid model — the island
    // run's virtual makespan must be several times smaller.
    let budget = 320u64;
    let nodes = 8usize;
    let evaluator = Arc::new(Zdt1Evaluator { dim: 2 }); // 1 s nominal/eval
    let cfg = {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(16, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0)
            .unwrap()
    };
    let pool = Arc::new(ThreadPool::new(4));

    // generational GA delegating every evaluation as its own grid job
    let env_g = EgiEnvironment::new("biomed", nodes, Arc::clone(&pool), 61);
    let generational = GenerationalGA::new(cfg.clone(), Arc::clone(&evaluator) as _, 16);
    let g = generational
        .run(&env_g, (budget / 16 - 1) as u32, 3)
        .unwrap()
        .virtual_makespan;

    // island model: 8 concurrent islands of 40 evaluations each
    let env_i = EgiEnvironment::new("biomed", nodes, pool, 62);
    let islands = IslandSteadyGA::new(
        cfg,
        IslandConfig {
            concurrent_islands: nodes,
            total_evaluations: budget,
            island_sample: 8,
            evals_per_island: 40,
        },
        Arc::clone(&evaluator) as _,
    );
    let i = islands.run(&env_i, 3, None).unwrap().virtual_makespan;

    assert!(
        i * 2.0 < g,
        "islands ({i:.0} s) must be at least 2x faster than per-evaluation \
         generational delegation ({g:.0} s) on the grid"
    );
}

#[test]
fn deterministic_island_runs_under_same_seed() {
    let evaluator = Arc::new(Zdt1Evaluator { dim: 2 });
    let run = |seed: u64| {
        let env = LocalEnvironment::new(1); // single worker: deterministic order
        let ga = IslandSteadyGA::new(
            {
                let x0 = val_f64("x0");
                let x1 = val_f64("x1");
                let f1 = val_f64("f1");
                let f2 = val_f64("f2");
                Nsga2Config::new(8, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0)
                    .unwrap()
            },
            IslandConfig {
                concurrent_islands: 1,
                total_evaluations: 40,
                island_sample: 4,
                evals_per_island: 10,
            },
            Arc::clone(&evaluator) as _,
        );
        let r = ga.run(&env, seed, None).unwrap();
        let mut objs: Vec<Vec<f64>> =
            r.population.iter().map(|i| i.objectives.clone()).collect();
        objs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        objs
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}
