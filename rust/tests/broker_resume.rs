//! Acceptance test for the distribution broker (§tentpole): a journaled
//! calibration against a broker with an injected-failure backend, killed
//! mid-run and resumed from its journal, must reach the same final
//! Pareto front — bit-identical objectives — as an uninterrupted run
//! with the same seed.

use std::path::PathBuf;
use std::sync::Arc;

use molers::broker::{journal, Broker, FlakyEnv, Journal, RoundRobin};
use molers::core::val_f64;
use molers::environment::local::LocalEnvironment;
use molers::environment::Environment;
use molers::evolution::{
    EvolutionResult, GenerationalGA, Nsga2Config, Zdt1Evaluator,
};
use molers::exec::ThreadPool;

fn config(mu: usize) -> Nsga2Config {
    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    let x2 = val_f64("x2");
    let f1 = val_f64("f1");
    let f2 = val_f64("f2");
    Nsga2Config::new(
        mu,
        &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
        &[&f1, &f2],
        0.25, // exercise the reevaluation path across the kill point
    )
    .unwrap()
}

/// A broker whose first backend drops 40% of submissions: every failed
/// job must be re-routed to the healthy backend for the run to finish.
fn faulty_broker(pool: &Arc<ThreadPool>, seed: u64) -> Broker {
    let flaky: Arc<dyn Environment> = Arc::new(FlakyEnv::new(
        Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))),
        0.4,
        seed,
    ));
    Broker::builder("faulty-fleet")
        .backend(flaky, 2)
        .backend(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))),
            2,
        )
        .policy(Box::new(RoundRobin::new()))
        .no_speculation()
        .build()
        .unwrap()
}

fn ga() -> GenerationalGA {
    GenerationalGA::new(config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
}

fn front(r: &EvolutionResult) -> Vec<Vec<f64>> {
    r.pareto_front.iter().map(|i| i.objectives.clone()).collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-resume-{}-{name}.jsonl", std::process::id()))
}

#[test]
fn kill_and_resume_reaches_identical_pareto_front() {
    const SEED: u64 = 29;
    const GENERATIONS: u32 = 6;
    let pool = Arc::new(ThreadPool::new(2));

    // reference: uninterrupted journaled run against the faulty fleet
    let path_full = tmp("full");
    let env_full = faulty_broker(&pool, 1);
    let full = ga()
        .journal(Arc::new(Journal::create(&path_full).unwrap()))
        .run(&env_full, GENERATIONS, SEED)
        .unwrap();
    assert!(
        env_full.stats().failed_attempts > 0,
        "the injected-failure backend never fired — the test is vacuous"
    );
    assert_eq!(env_full.stats().failed_jobs, 0, "broker must rescue every job");

    // the same run killed after generation 3 (fresh broker, different
    // fault pattern — the journal, not the environment, carries state)
    let path_cut = tmp("cut");
    let env_cut = faulty_broker(&pool, 2);
    ga().journal(Arc::new(Journal::create(&path_cut).unwrap()))
        .run(&env_cut, 3, SEED)
        .unwrap();

    // resume from the journal on a third broker and finish
    let resume = journal::load_resume(&path_cut)
        .unwrap()
        .expect("journal has a generation checkpoint");
    assert_eq!(resume.generation, 3);
    let env_resume = faulty_broker(&pool, 3);
    let resumed = ga()
        .journal(Arc::new(Journal::append_to(&path_cut).unwrap()))
        .run_resumable(&env_resume, GENERATIONS, SEED, Some(resume))
        .unwrap();

    assert_eq!(
        front(&full),
        front(&resumed),
        "kill + --resume must reach a bit-identical Pareto front"
    );
    assert_eq!(full.evaluations, resumed.evaluations);

    // the continued journal is itself a valid, complete record
    let records = Journal::load(&path_cut).unwrap();
    let last = journal::resume_state(&records).unwrap();
    assert_eq!(last.generation, GENERATIONS);

    let _ = std::fs::remove_file(&path_full);
    let _ = std::fs::remove_file(&path_cut);
}

#[test]
fn brokered_calibration_is_transparent() {
    // the paper's claim, broker edition: switching from one environment
    // to a faulty brokered fleet changes nothing about the result
    let pool = Arc::new(ThreadPool::new(2));
    let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
        r.population.iter().map(|i| i.objectives.clone()).collect()
    };
    let single = ga().run(&LocalEnvironment::new(2), 5, 11).unwrap();
    let brokered = ga().run(&faulty_broker(&pool, 7), 5, 11).unwrap();
    assert_eq!(
        objs(&single),
        objs(&brokered),
        "brokering must be invisible to the optimisation trajectory"
    );
}
