//! Hostile-client and protocol-robustness harness for `molers serve`
//! (§Durable-by-construction tentpole, parts 2–4): drive the real daemon
//! binary with garbage-spewing, oversized, slow-loris and half-closed
//! connections while a well-behaved tenant keeps working; shed past
//! `--max-conns`; prove `dedup_key` idempotency end-to-end (including
//! across a kill -9 restart); and prove a killed `watch` client resumes
//! gap-free with `after_seq`.

use std::collections::BTreeSet;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use molers::util::json::{self, Json};

const SIM_TICKS: &str = "40";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("molers-hostile-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A running daemon; killed on drop so a failing test never leaks it.
struct Daemon {
    child: Child,
    addr: String,
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn `molers serve` on an ephemeral port and wait until it accepts.
fn start_server(dir: &Path, extra: &[&str]) -> Daemon {
    let addr_file = dir.join("addr");
    let _ = std::fs::remove_file(&addr_file);
    let child = Command::new(env!("CARGO_BIN_EXE_molers"))
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .env("MOLERS_SIM_TICKS", SIM_TICKS)
        .args(["serve", "--addr", "127.0.0.1:0", "--state-dir"])
        .arg(dir)
        .args(extra)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn molers serve");
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Ok(text) = std::fs::read_to_string(&addr_file) {
            let addr = text.trim().to_string();
            if !addr.is_empty() && TcpStream::connect(&addr).is_ok() {
                break addr;
            }
        }
        assert!(Instant::now() < deadline, "daemon never came up");
        std::thread::sleep(Duration::from_millis(20));
    };
    Daemon { child, addr }
}

/// One request line → one response line, parsed.
fn request(addr: &str, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).expect("connect");
    writeln!(stream, "{line}").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    json::parse(resp.trim_end()).unwrap_or_else(|e| panic!("bad response `{resp}`: {e}"))
}

fn ping_ok(addr: &str) {
    let resp = request(addr, "{\"cmd\":\"ping\"}");
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
}

fn state_of(status: &Json) -> String {
    status
        .get("state")
        .and_then(Json::as_str)
        .unwrap_or("?")
        .to_string()
}

fn is_terminal(state: &str) -> bool {
    matches!(state, "done" | "degraded" | "failed" | "cancelled")
}

fn wait_terminal(addr: &str, id: u64, timeout: Duration) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let s = request(addr, &format!("{{\"cmd\":\"status\",\"id\":{id}}}"));
        if is_terminal(&state_of(&s)) {
            return s;
        }
        assert!(
            Instant::now() < deadline,
            "experiment {id} never finished: {s}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn hostile_connections_never_stop_a_well_behaved_tenant() {
    let dir = tmp_dir("hostile");
    let daemon = start_server(&dir, &["--envs", "local:2", "--conn-timeout", "1"]);
    let addr = &daemon.addr;

    // a slow loris: half a request line, then silence — parked in the
    // background while the real work below proceeds
    let mut loris = TcpStream::connect(addr).unwrap();
    loris.write_all(b"{\"cmd\":\"pi").unwrap();
    loris.flush().unwrap();

    // a half-closed connection: never sends a byte
    let half = TcpStream::connect(addr).unwrap();
    half.shutdown(Shutdown::Write).unwrap();

    // binary garbage gets an error line AND the connection stays usable
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"\xfe\xff\x00 binary \xff\n").unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(
            line.contains("\"ok\":false") && line.contains("UTF-8"),
            "garbage line answered: {line}"
        );
        // same connection, now well-behaved: still served
        writeln!(s, "{{\"cmd\":\"ping\"}}").unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":true"), "{line}");
    }

    // malformed JSON gets the parse error, not a dropped thread
    {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{{this is not json").unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "{line}");
    }

    // a newline-less flood is cut off at the line cap with an error
    // (exactly cap + 1 bytes, so the whole flood is consumed and the
    // error line comes back before the server closes)
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&vec![b'a'; 64 * 1024 + 1]).unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(
            line.contains("request line exceeds"),
            "flood answered: {line}"
        );
    }

    // meanwhile the well-behaved tenant's submission runs to completion
    let resp = request(
        addr,
        "{\"cmd\":\"submit\",\"run\":\"explore\",\"tenant\":\"good\",\
         \"options\":{\"n\":\"8\",\"chunk\":\"4\"}}",
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    let id = resp.get("id").and_then(Json::as_f64).unwrap() as u64;
    let done = wait_terminal(addr, id, Duration::from_secs(120));
    assert_eq!(state_of(&done), "done", "{done}");

    // the loris has been timed out by now (read timeout 1 s): EOF or a
    // reset, never a hung daemon thread
    loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut sink = Vec::new();
    let _ = loris.read_to_end(&mut sink);

    // the half-closed connection was unwound the same way
    drop(half);
    ping_ok(addr);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn connections_past_the_cap_are_shed_with_server_busy() {
    let dir = tmp_dir("shed");
    let daemon = start_server(
        &dir,
        &["--envs", "local:1", "--max-conns", "1", "--conn-timeout", "30"],
    );
    let addr = &daemon.addr;

    // occupy the single slot with an idle (but accepted) connection
    let hog = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    // the next connection is shed with one error line, not queued
    let over = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(over);
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("server busy"), "shed response: {line}");

    // releasing the slot restores service
    drop(hog);
    std::thread::sleep(Duration::from_millis(300));
    ping_ok(addr);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dedup_key_is_idempotent_end_to_end_and_across_restart() {
    let dir = tmp_dir("dedup");
    let submit_line = "{\"cmd\":\"submit\",\"run\":\"explore\",\"tenant\":\"alice\",\
         \"options\":{\"n\":\"8\",\"chunk\":\"4\"},\"dedup_key\":\"job-1\"}";
    let first;
    {
        let daemon = start_server(&dir, &["--envs", "local:2"]);
        let addr = &daemon.addr;
        let resp = request(addr, submit_line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        first = resp.get("id").and_then(Json::as_f64).unwrap() as u64;
        assert_eq!(resp.get("deduped"), None, "fresh submit is not a dup");

        // the client's response "was lost": the retry returns the same id
        let retry = request(addr, submit_line);
        assert_eq!(
            retry.get("id").and_then(Json::as_f64).unwrap() as u64,
            first
        );
        assert_eq!(retry.get("deduped"), Some(&Json::Bool(true)), "{retry}");

        // a different tenant's identical key is a different namespace
        let other = request(
            addr,
            "{\"cmd\":\"submit\",\"run\":\"explore\",\"tenant\":\"bob\",\
             \"options\":{\"n\":\"8\",\"chunk\":\"4\"},\"dedup_key\":\"job-1\"}",
        );
        assert_ne!(
            other.get("id").and_then(Json::as_f64).unwrap() as u64,
            first
        );

        let done = wait_terminal(addr, first, Duration::from_secs(120));
        assert_eq!(state_of(&done), "done", "{done}");
        // daemon killed here (Drop = kill -9)
    }

    // the key was journaled with the submission: a restarted daemon
    // still answers the retry with the original id — and never re-runs
    // the finished experiment
    let daemon = start_server(&dir, &["--envs", "local:2"]);
    let addr = &daemon.addr;
    let retry = request(addr, submit_line);
    assert_eq!(
        retry.get("id").and_then(Json::as_f64).unwrap() as u64,
        first,
        "{retry}"
    );
    assert_eq!(retry.get("deduped"), Some(&Json::Bool(true)), "{retry}");
    assert_eq!(
        retry.get("state"),
        Some(&Json::Str("done".into())),
        "the dedup response carries the original's current state: {retry}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Read `{"event":...}` lines off a watch stream, recording seqs, until
/// `limit` events have been seen, a terminal state arrives, or the
/// stream ends. Returns whether a terminal state was seen.
fn drain_watch(
    reader: &mut BufReader<TcpStream>,
    seqs: &mut BTreeSet<u64>,
    limit: usize,
) -> bool {
    let mut seen = 0usize;
    let mut line = String::new();
    while seen < limit {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return false,
            Ok(_) => {}
        }
        let ev = json::parse(line.trim_end())
            .unwrap_or_else(|e| panic!("bad watch line `{line}`: {e}"));
        assert_ne!(
            ev.get("ok"),
            Some(&Json::Bool(false)),
            "watch rejected: {ev}"
        );
        let seq = ev.get("seq").and_then(Json::as_f64).expect("seq on every event") as u64;
        seqs.insert(seq);
        seen += 1;
        if ev.get("event").and_then(Json::as_str) == Some("state")
            && is_terminal(&state_of(&ev))
        {
            return true;
        }
    }
    false
}

#[test]
fn a_killed_watch_client_resumes_gap_free_with_after_seq() {
    let dir = tmp_dir("watchgap");
    let daemon = start_server(&dir, &["--envs", "local:2"]);
    let addr = &daemon.addr;

    let resp = request(
        addr,
        "{\"cmd\":\"submit\",\"run\":\"explore\",\"tenant\":\"w\",\
         \"options\":{\"n\":\"240\",\"chunk\":\"2\",\"sampling\":\"sobol\"}}",
    );
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    let id = resp.get("id").and_then(Json::as_f64).unwrap() as u64;

    // first watcher: read a handful of events, then die mid-stream
    // (dropping the socket is what kill -9 on the client looks like)
    let mut seqs = BTreeSet::new();
    let terminal_early = {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{{\"cmd\":\"watch\",\"id\":{id}}}").unwrap();
        s.flush().unwrap();
        let mut r = BufReader::new(s);
        drain_watch(&mut r, &mut seqs, 5)
    };
    assert!(!seqs.is_empty(), "first watch saw events");

    // reconnect with after_seq = last seen: the server replays the
    // missed tail, then streams live until terminal
    if !terminal_early {
        let after = *seqs.iter().max().unwrap();
        let deadline = Instant::now() + Duration::from_secs(120);
        let mut done = false;
        while !done {
            assert!(Instant::now() < deadline, "watch never reached terminal");
            let mut s = TcpStream::connect(addr).unwrap();
            let resume_from = *seqs.iter().max().unwrap();
            writeln!(
                s,
                "{{\"cmd\":\"watch\",\"id\":{id},\"after_seq\":{resume_from}}}"
            )
            .unwrap();
            s.flush().unwrap();
            let mut r = BufReader::new(s);
            done = drain_watch(&mut r, &mut seqs, usize::MAX);
        }
        assert!(
            *seqs.iter().max().unwrap() > after,
            "the reconnected stream advanced past the drop point"
        );
    }

    // gap-free: the union of both connections' seqs is contiguous —
    // nothing between the first event seen and the terminal state was
    // skipped by the drop/reconnect
    let (lo, hi) = (*seqs.iter().min().unwrap(), *seqs.iter().max().unwrap());
    assert_eq!(
        hi - lo + 1,
        seqs.len() as u64,
        "seq union has holes: {seqs:?}"
    );
    let done = wait_terminal(addr, id, Duration::from_secs(60));
    assert_eq!(state_of(&done), "done", "{done}");
    let _ = std::fs::remove_dir_all(&dir);
}
