//! Chaos acceptance (§Robustness tentpole): a seeded [`FaultPlan`]
//! combining drops, hangs, stragglers and a crash window, driven through
//! the brokered sweep stack.
//!
//! * when retry budgets suffice, a chaos run is **byte-identical** to the
//!   fault-free run — the injected faults are fully absorbed by the
//!   broker's retry/timeout machinery;
//! * when they don't, `--degraded-ok` journals the **exact** failed row
//!   set as `degraded_rows`, NaN-fills those rows and reports a
//!   `degraded` (not failed) outcome;
//! * a `--resume` after degradation restores the NaN placeholders
//!   without re-evaluating them unless `--retry-degraded`;
//! * a fully hung fleet can never block a sweep past its real-time job
//!   deadline.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use molers::broker::{journal, Broker, Journal, RoundRobin};
use molers::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};
use molers::prelude::*;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-chaos-{}-{name}", std::process::id()))
}

fn sampling(n: usize) -> Arc<dyn Sampling> {
    let x = val_f64("x0");
    let y = val_f64("x1");
    Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n))
}

fn zdt2() -> Arc<dyn molers::evolution::Evaluator> {
    Arc::new(Zdt1Evaluator { dim: 2 })
}

fn read(p: &std::path::Path) -> Vec<u8> {
    std::fs::read(p).unwrap_or_else(|e| panic!("{p:?}: {e}"))
}

/// (a) 10k rows through a fleet of one healthy backend and one chaotic
/// backend injecting drops, hangs, stragglers and a crash window: the
/// retry budget suffices, so the result file is byte-identical to the
/// fault-free run and no job is lost.
#[test]
fn chaos_run_with_sufficient_retry_budget_is_byte_identical_to_fault_free() {
    let (n, chunk, seed) = (10_000usize, 64usize, 42u64);

    // fault-free reference
    let plain_csv = tmp("plain.csv");
    let writer = Arc::new(
        RowWriter::create(&plain_csv, TableFormat::Csv, &["x0", "x1", "f1", "f2"])
            .unwrap(),
    );
    let reference = Sweep::new(sampling(n), zdt2(), &["f1", "f2"])
        .chunk(chunk)
        .writer(writer)
        .run(&LocalEnvironment::new(4), seed)
        .unwrap();
    assert_eq!(reference.evaluated, n);

    // the same sweep through a chaotic fleet
    let plan = FaultPlan::new()
        .drops(0.15)
        .hangs(0.05)
        .stragglers(0.1, 30.0)
        .crash_window(5, 3);
    let chaotic = Arc::new(FaultyEnv::new(
        Arc::new(LocalEnvironment::new(2)),
        plan,
        0xFA11,
    ));
    let broker = Broker::builder("chaos-fleet")
        .backend(Arc::new(LocalEnvironment::new(4)), 4)
        .backend(Arc::clone(&chaotic) as Arc<dyn Environment>, 2)
        .policy(Box::new(RoundRobin::new()))
        .retry(RetryPolicy {
            max_attempts: 8,
            attempt_timeout_s: 1.0,
            job_deadline_s: 60.0,
            backoff_base_s: 0.1,
            backoff_max_s: 1.0,
            jitter: 0.5,
        })
        .seed(seed)
        .build()
        .unwrap();
    let chaos_csv = tmp("chaos.csv");
    let writer = Arc::new(
        RowWriter::create(&chaos_csv, TableFormat::Csv, &["x0", "x1", "f1", "f2"])
            .unwrap(),
    );
    let result = Sweep::new(sampling(n), zdt2(), &["f1", "f2"])
        .chunk(chunk)
        .writer(writer)
        .run(&broker, seed)
        .unwrap();

    assert_eq!(result.evaluated, n, "every row rescued");
    assert_eq!(result.outcome(), "complete");
    assert_eq!(
        read(&chaos_csv),
        read(&plain_csv),
        "chaos run must be byte-identical to the fault-free run"
    );

    // the crash window fired on exactly its three submissions, and the
    // ledger reconciles with every injected fault accounted for
    let inj = chaotic.injected();
    assert_eq!(inj.crash_failures, 3);
    assert!(inj.drops > 0, "15% drop rate over ~half the jobs");
    let s = broker.stats();
    assert_eq!(s.failed_jobs, 0);
    assert_eq!(s.submitted, s.completed);
    assert_eq!(s.failed_attempts, s.resubmissions + s.failed_jobs);
    assert_eq!(s.in_flight(), 0, "no orphaned in-flight jobs");

    for p in [&plain_csv, &chaos_csv] {
        let _ = std::fs::remove_file(p);
    }
}

/// (b) + (c): a crash window on a single-backend fleet with no retry
/// budget degrades exactly the affected rows; the journal names them, the
/// CSV NaN-fills them, and a resume restores them without re-evaluation
/// unless `--retry-degraded`.
#[test]
fn degraded_rows_are_journaled_exactly_and_resume_without_reevaluation() {
    let (n, chunk, seed) = (60usize, 10usize, 7u64);

    // fault-free reference objectives
    let reference = Sweep::new(sampling(n), zdt2(), &["f1", "f2"])
        .chunk(chunk)
        .run(&LocalEnvironment::new(2), seed)
        .unwrap();

    // submissions 2 and 3 (rows 20..40) die terminally: one attempt each
    let chaotic = Arc::new(FaultyEnv::new(
        Arc::new(LocalEnvironment::new(2)),
        FaultPlan::new().crash_window(2, 2),
        0x5EED,
    ));
    let broker = Broker::builder("degraded-fleet")
        .backend(chaotic as Arc<dyn Environment>, 2)
        .max_attempts(1)
        .seed(seed)
        .build()
        .unwrap();
    let j_path = tmp("degraded.jsonl");
    let csv = tmp("degraded.csv");
    let writer = Arc::new(
        RowWriter::create(&csv, TableFormat::Csv, &["x0", "x1", "f1", "f2"]).unwrap(),
    );
    let result = Sweep::new(sampling(n), zdt2(), &["f1", "f2"])
        .chunk(chunk)
        .degraded_ok(true)
        .journal(Arc::new(Journal::create(&j_path).unwrap()))
        .writer(writer)
        .run(&broker, seed)
        .unwrap();

    let failed: Vec<usize> = (20..40).collect();
    assert_eq!(result.outcome(), "degraded");
    assert_eq!(result.degraded, failed);
    assert_eq!(result.evaluated, 40);

    // journal: the degraded_rows records name exactly the failed set
    let records = Journal::load(&j_path).unwrap();
    let mut journaled: Vec<usize> = journal::degraded_rows(&records)
        .into_iter()
        .flat_map(|d| d.rows)
        .collect();
    journaled.sort_unstable();
    assert_eq!(journaled, failed, "journal names the exact failed row set");
    assert!(records
        .iter()
        .any(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_end")));

    // CSV: NaN in exactly the degraded rows (header + 60 data rows)
    let text = String::from_utf8(read(&csv)).unwrap();
    let nan_rows: Vec<usize> = text
        .lines()
        .skip(1)
        .enumerate()
        .filter_map(|(r, line)| line.contains("NaN").then_some(r))
        .collect();
    assert_eq!(nan_rows, failed, "NaN objectives in exactly the failed rows");

    // resume WITHOUT --retry-degraded: nothing re-evaluates, NaN persists
    let events = journal::sweep_events(&records);
    let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
    let healthy = LocalEnvironment::new(2);
    let resumed = Sweep::new(sampling(n), Arc::clone(&counting) as _, &["f1", "f2"])
        .chunk(chunk)
        .degraded_ok(true)
        .run_resumable(&healthy, seed, Some(&events))
        .unwrap();
    assert_eq!(counting.count(), 0, "restored rows must not re-evaluate");
    assert_eq!(resumed.resumed, 40);
    assert_eq!(resumed.resumed_degraded, 20);
    assert_eq!(resumed.degraded, failed);
    assert!(resumed.objectives_row(25).iter().all(|v| v.is_nan()));

    // resume WITH --retry-degraded on a healthy environment: exactly the
    // degraded rows re-evaluate, and the result converges to fault-free
    let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
    let retried = Sweep::new(sampling(n), Arc::clone(&counting) as _, &["f1", "f2"])
        .chunk(chunk)
        .retry_degraded(true)
        .run_resumable(&healthy, seed, Some(&events))
        .unwrap();
    assert_eq!(counting.count(), 20, "only the degraded rows re-evaluate");
    assert_eq!(retried.outcome(), "complete");
    assert_eq!(retried.objectives, reference.objectives);

    for p in [&j_path, &csv] {
        let _ = std::fs::remove_file(p);
    }
}

/// (c) of the acceptance: a fleet where EVERY backend hangs EVERY job can
/// never block the sweep past the real-time job deadline — with
/// `--degraded-ok` it finishes (degraded) in bounded wall time.
#[test]
fn fully_hung_fleet_degrades_within_the_job_deadline() {
    let hung = Arc::new(FaultyEnv::new(
        Arc::new(LocalEnvironment::new(2)),
        FaultPlan::new().hangs(1.0),
        1,
    ));
    let broker = Broker::builder("hung-fleet")
        .backend(hung as Arc<dyn Environment>, 2)
        .retry(RetryPolicy {
            max_attempts: 100,
            attempt_timeout_s: 0.05,
            job_deadline_s: 0.2,
            backoff_base_s: 0.01,
            backoff_max_s: 0.01,
            jitter: 0.0,
        })
        .seed(9)
        .build()
        .unwrap();

    let t0 = Instant::now();
    let result = Sweep::new(sampling(8), zdt2(), &["f1", "f2"])
        .chunk(4)
        .degraded_ok(true)
        .run(&broker, 3)
        .unwrap();
    let elapsed = t0.elapsed().as_secs_f64();

    assert_eq!(result.outcome(), "degraded");
    assert_eq!(result.degraded, (0..8).collect::<Vec<_>>());
    assert!(
        elapsed < 30.0,
        "deadline must bound the wait, took {elapsed:.1}s"
    );
    let s = broker.stats();
    assert!(s.timed_out_attempts >= 2, "every attempt timed out: {s:?}");
    assert_eq!(s.failed_jobs, 2);
    assert_eq!(s.in_flight(), 0, "abandoned jobs must release in-flight");
}
