//! Property-based tests over coordinator invariants (routing, batching,
//! aggregation, selection). The `proptest` crate is not vendored in this
//! image, so these use a seeded-random harness: each property is checked
//! over many generated cases and failures print the offending seed.

use std::sync::Arc;

use molers::care::{reexec, Dependency, KernelVersion, Manifest};
use molers::environment::cluster::SimCluster;
use molers::evolution::{nsga2, Bounds, Individual, Operators};
use molers::prelude::*;
use molers::util::stats;

/// Run `prop` over `cases` generated inputs; panic with the seed on failure.
fn forall(cases: u64, prop: impl Fn(&mut Rng)) {
    for case in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ case);
        prop(&mut rng); // assertion failures name the case via panic payload
    }
}

fn random_population(rng: &mut Rng, n: usize, objectives: usize) -> Vec<Individual> {
    (0..n)
        .map(|_| {
            Individual::new(
                vec![rng.f64(), rng.f64()],
                (0..objectives).map(|_| rng.range(0.0, 10.0)).collect(),
            )
        })
        .collect()
}

// ---------------------------------------------------------------- NSGA-II

#[test]
fn prop_fronts_partition_population() {
    forall(50, |rng| {
        let (n, m) = (1 + rng.usize(40), 1 + rng.usize(3));
        let pop = random_population(rng, n, m);
        let fronts = nsga2::fast_non_dominated_sort(&pop);
        let mut seen: Vec<usize> = fronts.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..pop.len()).collect::<Vec<_>>(), "partition");
    });
}

#[test]
fn prop_front_zero_is_nondominated_and_earlier_fronts_dominate_later() {
    forall(50, |rng| {
        let n = 2 + rng.usize(30);
        let pop = random_population(rng, n, 2);
        let fronts = nsga2::fast_non_dominated_sort(&pop);
        for &i in &fronts[0] {
            for &j in &fronts[0] {
                assert!(!pop[i].dominates(&pop[j]) || i == j, "front0 internal dominance");
            }
        }
        // every member of front k>0 is dominated by someone in front k-1
        for k in 1..fronts.len() {
            for &j in &fronts[k] {
                assert!(
                    fronts[k - 1].iter().any(|&i| pop[i].dominates(&pop[j])),
                    "front {k} member {j} not dominated by front {}",
                    k - 1
                );
            }
        }
    });
}

#[test]
fn prop_selection_keeps_mu_and_never_drops_front0_when_it_fits() {
    forall(50, |rng| {
        let n = 5 + rng.usize(30);
        let pop = random_population(rng, n, 2);
        let mu = 1 + rng.usize(pop.len());
        let front0: Vec<Vec<f64>> = nsga2::pareto_front(&pop)
            .into_iter()
            .map(|i| i.objectives)
            .collect();
        let kept = nsga2::select(pop, mu);
        assert_eq!(kept.len(), mu);
        if front0.len() <= mu {
            for objs in &front0 {
                assert!(
                    kept.iter().any(|i| &i.objectives == objs),
                    "front-0 member evicted though it fit"
                );
            }
        }
    });
}

#[test]
fn prop_columnar_selection_matches_reference_aos() {
    // §Perf tentpole acceptance: the columnar PopMatrix/WaveArena
    // rank+crowding selection must pick the IDENTICAL survivor set as the
    // retained reference AoS implementation (evolution::reference) on
    // randomized populations — NaN objectives and duplicate-fitness ties
    // included. Comparison is bit-level (to_bits), so NaN survivors
    // compare equal and -0.0/+0.0 would not.
    use molers::evolution::{reference, PopMatrix, WaveArena};
    let key = |i: &Individual| -> (Vec<u64>, Vec<u64>, u32) {
        (
            i.genome.iter().map(|v| v.to_bits()).collect(),
            i.objectives.iter().map(|v| v.to_bits()).collect(),
            i.evaluations,
        )
    };
    forall(80, |rng| {
        let n = 1 + rng.usize(50);
        let m = 1 + rng.usize(3);
        let mu = 1 + rng.usize(n);
        // coarse grid values force duplicate fitness ties; ~7% NaN
        let mut pop: Vec<Individual> = (0..n)
            .map(|_| {
                let objs: Vec<f64> = (0..m)
                    .map(|_| {
                        if rng.bool(0.07) {
                            f64::NAN
                        } else {
                            f64::from(rng.usize(4) as u32)
                        }
                    })
                    .collect();
                Individual::new(vec![rng.f64(), rng.f64()], objs)
            })
            .collect();
        // and some exact whole-vector duplicates
        if n > 3 {
            let dup = pop[0].objectives.clone();
            pop[n / 2].objectives = dup.clone();
            pop[n - 1].objectives = dup;
        }

        let mut matrix = PopMatrix::from_individuals(&pop, 2, m).unwrap();
        let mut arena = WaveArena::default();
        arena.select(&mut matrix, mu, None);
        let got: Vec<_> = matrix.to_individuals().iter().map(key).collect();

        let want: Vec<_> = reference::select(pop, mu).iter().map(key).collect();
        assert_eq!(got, want, "columnar survivors diverged (n={n} m={m} mu={mu})");
    });
}

#[test]
fn prop_breeding_respects_bounds() {
    let d = val_f64("d");
    let e = val_f64("e");
    let bounds = Bounds::new(&[(&d, -3.0, 7.0), (&e, 0.0, 99.0)]).unwrap();
    let ops = Operators::default();
    forall(200, |rng| {
        let a = bounds.random(rng);
        let b = bounds.random(rng);
        let child = ops.breed(&a, &b, &bounds, rng);
        assert!(bounds.contains(&child), "child out of bounds: {child:?}");
    });
}

// ----------------------------------------------------------- scheduling

#[test]
fn prop_cluster_schedule_no_node_overlap() {
    forall(30, |rng| {
        let nodes = 1 + rng.usize(6);
        let mut cluster = SimCluster::homogeneous(nodes, 1.0);
        let mut per_node: Vec<Vec<(f64, f64)>> = vec![Vec::new(); nodes];
        for _ in 0..40 {
            let id = cluster.create_job();
            let release = rng.range(0.0, 50.0);
            let exec = rng.range(0.1, 20.0);
            let s = cluster.schedule(id, release, exec, 1e9, None).unwrap();
            assert!(s.start >= release - 1e-9, "started before release");
            per_node[s.node].push((s.start, s.end));
        }
        for intervals in &mut per_node {
            intervals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in intervals.windows(2) {
                assert!(w[0].1 <= w[1].0 + 1e-9, "overlap on a node: {w:?}");
            }
        }
    });
}

#[test]
fn prop_work_conservation_makespan_bounds() {
    // makespan of m equal jobs on n nodes is between total/n and
    // total/n + one job (greedy earliest-free assignment, equal sizes)
    forall(30, |rng| {
        let nodes = 1 + rng.usize(8);
        let jobs = 1 + rng.usize(50);
        let exec = rng.range(0.5, 10.0);
        let mut cluster = SimCluster::homogeneous(nodes, 1.0);
        let mut makespan = 0.0f64;
        for _ in 0..jobs {
            let id = cluster.create_job();
            let s = cluster.schedule(id, 0.0, exec, 1e9, None).unwrap();
            makespan = makespan.max(s.end);
        }
        let lower = exec * jobs as f64 / nodes as f64;
        assert!(makespan >= lower - 1e-9, "{makespan} < {lower}");
        assert!(makespan <= lower + exec + 1e-9, "{makespan} > {}", lower + exec);
    });
}

// ----------------------------------------------------------- aggregation

#[test]
fn prop_context_aggregate_preserves_order_and_length() {
    let v = val_f64("v");
    forall(50, |rng| {
        let n = 1 + rng.usize(20);
        let values: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let ctxs: Vec<Context> = values
            .iter()
            .map(|&x| Context::new().with(&v, x))
            .collect();
        let agg = Context::aggregate(&ctxs);
        assert_eq!(agg.get(&v.array()).unwrap(), values);
    });
}

#[test]
fn prop_statistics_descriptors_within_range() {
    forall(100, |rng| {
        let n = 1 + rng.usize(30);
        let xs: Vec<f64> = (0..n).map(|_| rng.range(-100.0, 100.0)).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for d in [
            Descriptor::Median,
            Descriptor::Mean,
            Descriptor::Quantile(25),
            Descriptor::Quantile(90),
        ] {
            let v = d.apply(&xs);
            assert!(
                (lo - 1e-9..=hi + 1e-9).contains(&v),
                "{d:?} = {v} outside [{lo}, {hi}]"
            );
        }
        assert!(stats::stddev(&xs) >= 0.0);
    });
}

// ----------------------------------------------------------- workflow

#[test]
fn prop_random_linear_workflows_run_to_completion() {
    // random chains of add/mul tasks compute the same value as a direct
    // fold, whatever the chain length
    let x = val_f64("x");
    forall(25, |rng| {
        let len = 1 + rng.usize(8);
        let ops: Vec<(bool, f64)> = (0..len)
            .map(|_| (rng.bool(0.5), rng.range(1.0, 3.0)))
            .collect();
        let builder = PuzzleBuilder::new();
        let mut prev: Option<CapsuleHandle> = None;
        for (is_add, k) in ops.clone() {
            let x2 = x.clone();
            let c = builder.task(
                ClosureTask::new("op", move |ctx: &Context| {
                    let v = ctx.get(&x2)?;
                    Ok(Context::new().with(&x2, if is_add { v + k } else { v * k }))
                })
                .input(&x)
                .output(&x),
            );
            if let Some(prev) = &prev {
                prev.then(&c);
            } else {
                c.entry();
            }
            prev = Some(c);
        }
        let init = Context::new().with(&x, 1.0);
        let p = builder.build_with(&init).unwrap();
        let r = MoleExecution::new(p, Arc::new(LocalEnvironment::new(2)), 1)
            .start_with(init)
            .unwrap();
        let want = ops
            .iter()
            .fold(1.0, |v, (add, k)| if *add { v + k } else { v * k });
        let got = r.outputs[0].get(&x).unwrap();
        assert!((got - want).abs() < 1e-9, "{got} != {want}");
    });
}

// ----------------------------------------------------------- packaging

#[test]
fn prop_care_always_reexecutes_cde_monotone_in_kernel() {
    forall(60, |rng| {
        let kernels = [
            KernelVersion(2, 6, 18),
            KernelVersion(2, 6, 32),
            KernelVersion(3, 10, 0),
            KernelVersion(4, 4, 0),
        ];
        let packaged = kernels[rng.usize(kernels.len())];
        let manifest = Manifest::new("app", "./app", packaged)
            .with(Dependency::lib("/lib/libc.so.6", "2.17"));
        let host_kernel = kernels[rng.usize(kernels.len())];
        let host = reexec::RemoteHost::new("h", host_kernel);
        // CARE: always succeeds
        assert!(reexec::reexecute(&manifest, reexec::Packager::Care, &host).is_success());
        // CDE: succeeds iff host kernel >= packaging kernel
        let cde_ok =
            reexec::reexecute(&manifest, reexec::Packager::Cde, &host).is_success();
        assert_eq!(cde_ok, host_kernel >= packaged);
    });
}

// ----------------------------------------------------------- samplings

#[test]
fn prop_lhs_stratifies_every_dimension() {
    use molers::exploration::{LhsSampling, Sampling};
    let x = val_f64("x");
    let y = val_f64("y");
    forall(20, |rng| {
        let n = 4 + rng.usize(12);
        let s = LhsSampling::new(&[(&x, 0.0, 1.0), (&y, -5.0, 5.0)], n);
        let samples = s.sample(&Context::new(), rng);
        assert_eq!(samples.len(), n);
        for (val, lo, hi) in [(&x, 0.0, 1.0), (&y, -5.0, 5.0)] {
            let mut seen = vec![false; n];
            for c in &samples {
                let v = c.get(val).unwrap();
                assert!((lo..hi).contains(&v), "outside bounds");
                let bin = (((v - lo) / (hi - lo) * n as f64) as usize).min(n - 1);
                assert!(!seen[bin], "two samples in stratum {bin}");
                seen[bin] = true;
            }
            assert!(seen.iter().all(|&b| b), "stratum unfilled");
        }
    });
}

#[test]
fn prop_full_factorial_size_matches_level_product() {
    use molers::exploration::{Factor, FullFactorial, Sampling};
    let x = val_f64("x");
    let y = val_f64("y");
    forall(30, |rng| {
        let sx = rng.range(0.1, 5.0);
        let sy = rng.range(0.1, 5.0);
        let s = FullFactorial::new(vec![
            Factor::new(&x, 0.0, 10.0, sx),
            Factor::new(&y, 0.0, 10.0, sy),
        ]);
        let samples = s.sample(&Context::new(), rng);
        assert_eq!(samples.len(), s.size());
        // no duplicate points
        let mut pts: Vec<(u64, u64)> = samples
            .iter()
            .map(|c| {
                (
                    c.get(&x).unwrap().to_bits(),
                    c.get(&y).unwrap().to_bits(),
                )
            })
            .collect();
        pts.sort_unstable();
        let before = pts.len();
        pts.dedup();
        assert_eq!(pts.len(), before, "duplicate factorial points");
    });
}

#[test]
fn prop_reevaluation_average_converges_to_true_mean() {
    use molers::evolution::Individual;
    forall(40, |rng| {
        let true_mean = rng.range(-10.0, 10.0);
        let mut ind = Individual::new(vec![], vec![true_mean + rng.range(-1.0, 1.0)]);
        let n = 50;
        let mut sum = ind.objectives[0];
        for _ in 1..n {
            let draw = true_mean + rng.range(-1.0, 1.0);
            sum += draw;
            ind.absorb_reevaluation(&[draw]);
        }
        let expect = sum / n as f64;
        assert!(
            (ind.objectives[0] - expect).abs() < 1e-9,
            "running average drifted: {} vs {expect}",
            ind.objectives[0]
        );
        assert_eq!(ind.evaluations, n);
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    use molers::util::json::{parse, Json};
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.usize(4) } else { rng.usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => Json::Str(format!("s{}-\"quoted\"\n", rng.usize(1000))),
            4 => Json::Arr((0..rng.usize(4)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(60, |rng| {
        let doc = gen(rng, 3);
        let text = doc.to_string();
        let parsed = parse(&text).unwrap_or_else(|e| panic!("reparse failed: {e}\n{text}"));
        assert_eq!(parsed, doc, "roundtrip mismatch for {text}");
    });
}

// ------------------------------------------------------------- samplings

/// §Exploration tentpole invariant: for every columnar sampling, the
/// streaming `sample_into` matrix path and the legacy `Context` path
/// produce identical designs from the same RNG stream (and consume
/// exactly the same number of draws). Meaningful for samplings that
/// override `sample` (ProductSampling), and pins the edge adapter for the
/// rest.
#[test]
fn prop_sample_into_matches_context_path() {
    let x = val_f64("x");
    let y = val_f64("y");
    let seedv = val_u32("seed");
    forall(20, |rng| {
        let stream_seed = rng.next_u64();
        let samplings: Vec<Arc<dyn Sampling>> = vec![
            Arc::new(FullFactorial::new(vec![
                Factor::new(&x, 0.0, 1.0, 0.3),
                Factor::new(&y, -1.0, 2.0, 0.7),
            ])),
            Arc::new(UniformSampling::new(&x, 0.0, 10.0, 17)),
            Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, 5.0, 9.0)], 23)),
            Arc::new(SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], 19)),
            Arc::new(SeedSampling::new(&seedv, 11)),
            Arc::new(ProductSampling::new(
                Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 2.0, 1.0)])),
                Arc::new(LhsSampling::new(&[(&y, 0.0, 1.0)], 5)),
            )),
        ];
        let base = Context::new().with(&val_f64("carried"), 42.0);
        for s in samplings {
            let mut ctx_rng = Rng::new(stream_seed);
            let contexts = s.sample(&base, &mut ctx_rng);
            let mut mat_rng = Rng::new(stream_seed);
            let mut m = SampleMatrix::new(s.columns());
            s.sample_into(&mut m, &mut mat_rng).unwrap();
            assert_eq!(m.len(), contexts.len(), "{} row count", s.name());
            assert_eq!(
                m.to_contexts(&base),
                contexts,
                "{} designs diverged between paths",
                s.name()
            );
            assert_eq!(
                ctx_rng.state(),
                mat_rng.state(),
                "{} consumed a different RNG stream per path",
                s.name()
            );
            if let Some(hint) = s.size_hint() {
                assert_eq!(hint, m.len(), "{} size_hint", s.name());
            }
        }
    });
}

/// Reusing one matrix across waves must reproduce a fresh matrix's design
/// exactly (the arena discipline cannot leak state between waves).
#[test]
fn prop_matrix_reuse_reproduces_fresh_designs() {
    let x = val_f64("x");
    let y = val_f64("y");
    forall(15, |rng| {
        let n = 1 + rng.usize(40);
        let stream_seed = rng.next_u64();
        let samplings: Vec<Arc<dyn Sampling>> = vec![
            Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, -3.0, 3.0)], n)),
            Arc::new(SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n)),
            Arc::new(UniformSampling::multi(&[(&x, 0.0, 1.0), (&y, 0.0, 5.0)], n)),
        ];
        for s in samplings {
            let mut reused = SampleMatrix::new(s.columns());
            // dirty the matrix and its scratch with a first wave
            s.sample_into(&mut reused, &mut Rng::new(stream_seed ^ 0xDEAD))
                .unwrap();
            reused.clear();
            s.sample_into(&mut reused, &mut Rng::new(stream_seed)).unwrap();
            let mut fresh = SampleMatrix::new(s.columns());
            s.sample_into(&mut fresh, &mut Rng::new(stream_seed)).unwrap();
            assert_eq!(reused.data(), fresh.data(), "{} reuse leaked state", s.name());
        }
    });
}
