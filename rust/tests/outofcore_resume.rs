//! Out-of-core explore acceptance (§Exploration at memory-bounded
//! scale): a sobol sweep under `--mem-budget` streams the design in
//! bounded windows and spills completed rows to disk, yet must produce a
//! result file **byte-identical** to the unspilled reference — including
//! after a `kill -9` at *every* block boundary followed by `--resume`,
//! and across both journal layouts (legacy single-file and segmented).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use molers::broker::{journal, Durability, Journal};
use molers::evolution::evaluator::Zdt1Evaluator;
use molers::exploration::{Sampling, SobolSampling, Sweep};
use molers::prelude::*;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-ooc-{}-{name}", std::process::id()))
}

fn sampling(n: usize) -> Arc<dyn Sampling> {
    let x = val_f64("x0");
    let y = val_f64("x1");
    Arc::new(SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n))
}

/// Simulate `kill -9`: keep the journal's `run_start` plus the first
/// `keep_blocks` checkpoints, then a torn half-written line.
fn killed_journal(full: &Path, cut: &Path, keep_blocks: usize) -> usize {
    let text = std::fs::read_to_string(full).unwrap();
    let mut out = String::new();
    let mut kept_rows = 0;
    let mut blocks = 0;
    for line in text.lines() {
        let is_block = line.contains("\"kind\":\"sample_block\"");
        if is_block && blocks >= keep_blocks {
            continue;
        }
        if line.contains("\"kind\":\"env_stats\"") || line.contains("\"kind\":\"run_end\"") {
            continue;
        }
        if is_block {
            blocks += 1;
            let rec = molers::util::json::parse(line).unwrap();
            kept_rows += rec.get("rows").unwrap().as_usize().unwrap();
        }
        out.push_str(line);
        out.push('\n');
    }
    out.push_str("{\"kind\":\"sample_blo"); // torn mid-write
    std::fs::write(cut, out).unwrap();
    kept_rows
}

/// One explore run. `budget: Some(_)` takes the streaming out-of-core
/// path (spilling under `spill`, or the temp dir); `None` is the
/// in-RAM reference path.
#[allow(clippy::too_many_arguments)]
fn run_explore(
    n: usize,
    chunk: usize,
    seed: u64,
    out_path: &Path,
    budget: Option<u64>,
    spill: Option<&Path>,
    j: Option<Journal>,
    resume: Option<&[journal::SweepEvent]>,
) -> molers::exploration::SweepResult {
    let columns = ["x0", "x1", "f1", "f2"];
    let writer = Arc::new(RowWriter::create(out_path, TableFormat::Csv, &columns).unwrap());
    let env = LocalEnvironment::new(2);
    let mut sweep = Sweep::new(sampling(n), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
        .chunk(chunk)
        .writer(writer)
        .mem_budget(budget)
        .spill_dir(spill.map(Path::to_path_buf));
    if let Some(j) = j {
        sweep = sweep.journal(Arc::new(j));
    }
    sweep.run_resumable(&env, seed, resume).unwrap()
}

#[test]
fn spilled_sobol_matches_unspilled_reference_byte_for_byte() {
    let (n, chunk, seed) = (512, 8, 5u64);
    let ref_csv = tmp("ref.csv");
    let ooc_csv = tmp("ooc.csv");
    let spill = tmp("spill-dir");

    let reference = run_explore(n, chunk, seed, &ref_csv, None, None, None, None);
    assert_eq!(reference.evaluated, n);
    let want = std::fs::read(&ref_csv).unwrap();

    // a budget far below the design size: the full objective set is
    // n * 4 columns * 8 bytes = 16 KiB, the budget allows 1 KiB resident
    let spilled = run_explore(
        n,
        chunk,
        seed,
        &ooc_csv,
        Some(1024),
        Some(&spill),
        None,
        None,
    );
    assert_eq!(spilled.evaluated, n);
    assert_eq!(spilled.rows(), n);
    assert_eq!(
        std::fs::read(&ooc_csv).unwrap(),
        want,
        "spilled CSV must be byte-identical to the in-RAM reference"
    );

    // the budget bounds resident storage: the high-water mark stays far
    // below materialising the design + objectives in RAM
    let full_bytes = (n * 4 * 8) as u64;
    assert!(spilled.peak_resident_bytes > 0, "high-water mark recorded");
    assert!(
        spilled.peak_resident_bytes < full_bytes / 2,
        "peak {} must stay well under the {} bytes an in-RAM run holds",
        spilled.peak_resident_bytes,
        full_bytes
    );

    let _ = std::fs::remove_dir_all(&spill);
    for p in [&ref_csv, &ooc_csv] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn spilled_kill_and_resume_at_every_block_boundary_is_byte_identical() {
    let (n, chunk, seed) = (48, 8, 7u64);
    let blocks = n / chunk;
    let ref_csv = tmp("bnd-ref.csv");
    let full_j = tmp("bnd-full.jsonl");
    let full_csv = tmp("bnd-full.csv");

    // unspilled reference bytes, then a full *spilled* run with a legacy
    // single-file journal to harvest checkpoints from
    run_explore(n, chunk, seed, &ref_csv, None, None, None, None);
    let want = std::fs::read(&ref_csv).unwrap();
    run_explore(
        n,
        chunk,
        seed,
        &full_csv,
        Some(512),
        None,
        Some(Journal::create(&full_j).unwrap()),
        None,
    );
    assert_eq!(std::fs::read(&full_csv).unwrap(), want);

    for keep in 0..=blocks {
        let cut_j = tmp(&format!("bnd-cut-{keep}.jsonl"));
        let cut_csv = tmp(&format!("bnd-cut-{keep}.csv"));
        let kept_rows = killed_journal(&full_j, &cut_j, keep);
        let events = journal::sweep_events(&Journal::load(&cut_j).unwrap());
        assert_eq!(events.len(), keep.min(blocks));

        let resumed = run_explore(
            n,
            chunk,
            seed,
            &cut_csv,
            Some(512),
            None,
            Some(Journal::append_to(&cut_j).unwrap()),
            Some(&events),
        );
        assert_eq!(resumed.resumed, kept_rows, "kill after {keep} blocks");
        assert_eq!(resumed.evaluated, n - kept_rows);
        assert_eq!(
            std::fs::read(&cut_csv).unwrap(),
            want,
            "resume after {keep} checkpointed blocks must be byte-identical"
        );
        for p in [&cut_j, &cut_csv] {
            let _ = std::fs::remove_file(p);
        }
    }

    for p in [&ref_csv, &full_j, &full_csv] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn spilled_resume_replays_a_segmented_journal() {
    let (n, chunk, seed) = (30, 6, 11u64);
    let ref_csv = tmp("seg-ref.csv");
    let seg_j = tmp("seg.jsonl");
    let res_csv = tmp("seg-res.csv");

    run_explore(n, chunk, seed, &ref_csv, None, None, None, None);
    let want = std::fs::read(&ref_csv).unwrap();

    // a rolling journal: run_start + 5 blocks + env_stats + run_end
    // across roll_every=3 spreads the history over several segments
    run_explore(
        n,
        chunk,
        seed,
        &tmp("seg-full.csv"),
        Some(512),
        None,
        Some(Journal::create_rolling(&seg_j, Durability::Os, 3).unwrap()),
        None,
    );
    let segments = journal::journal_segments(&seg_j);
    assert!(
        segments.len() > 1,
        "rolling journal must have split: {segments:?}"
    );

    // the segmented layout replays as one history: every row restores,
    // nothing re-evaluates, bytes match the reference
    let records = Journal::load_segmented(&seg_j).unwrap();
    let events = journal::sweep_events(&records);
    let resumed = run_explore(
        n,
        chunk,
        seed,
        &res_csv,
        Some(512),
        None,
        Some(Journal::append_to_rolling(&seg_j, Durability::Os, 3).unwrap()),
        Some(&events),
    );
    assert_eq!(resumed.resumed, n);
    assert_eq!(resumed.evaluated, 0);
    assert_eq!(
        std::fs::read(&res_csv).unwrap(),
        want,
        "segmented resume must be byte-identical"
    );

    // re-list: the resume run may have rolled further segments
    for (_, p) in journal::journal_segments(&seg_j) {
        let _ = std::fs::remove_file(p);
    }
    for p in [&ref_csv, &res_csv, &tmp("seg-full.csv")] {
        let _ = std::fs::remove_file(p);
    }
}
