//! Crash-recovery harness (§Robustness tentpole, part 4): a brokered
//! chaos sweep is "killed" at **every** journal record boundary — the
//! journal is truncated to each prefix of records plus a torn,
//! half-written final line, exactly what `kill -9` leaves behind — and
//! resumed. Every resume must produce a result file **byte-identical** to
//! the uninterrupted reference run.
//!
//! This works because the design and every per-row model seed are pure
//! functions of `(sampling, seed, row)`: whatever subset of rows the
//! journal prefix restores, re-evaluating the rest on a different broker
//! with different faults injected reproduces the same objectives.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use molers::broker::{journal, Broker, Journal};
use molers::evolution::evaluator::Zdt1Evaluator;
use molers::exec::ThreadPool;
use molers::prelude::*;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-recov-{}-{name}", std::process::id()))
}

fn sampling(n: usize) -> Arc<dyn Sampling> {
    let x = val_f64("x0");
    let y = val_f64("x1");
    Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n))
}

/// A representative chaos fleet from the `--envs` FaultPlan grammar: one
/// healthy backend plus one that drops 30% of submissions and stretches
/// 20% into stragglers — every chunk survives via the retry budget.
fn chaos_broker(seed: u64) -> Broker {
    let pool = Arc::new(ThreadPool::new(2));
    Broker::from_spec("local:2,local:2~drop=0.3;delay=0.2:10", pool, seed).unwrap()
}

fn run_sweep(
    n: usize,
    chunk: usize,
    seed: u64,
    journal_path: Option<&Path>,
    out_path: &Path,
    resume: Option<&[journal::SweepEvent]>,
) -> molers::exploration::SweepResult {
    let writer = Arc::new(
        RowWriter::create(out_path, TableFormat::Csv, &["x0", "x1", "f1", "f2"])
            .unwrap(),
    );
    let mut sweep = Sweep::new(sampling(n), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
        .chunk(chunk)
        .writer(writer);
    if let Some(p) = journal_path {
        sweep = sweep.journal(Arc::new(Journal::create(p).unwrap()));
    }
    let env = chaos_broker(seed ^ 0xC4A5);
    sweep.run_resumable(&env, seed, resume).unwrap()
}

#[test]
fn resume_at_every_journal_record_boundary_is_byte_identical() {
    let (n, chunk, seed) = (60usize, 8usize, 13u64);
    let full_j = tmp("ref.jsonl");
    let full_csv = tmp("ref.csv");

    // uninterrupted chaos reference: journal + result file
    let reference = run_sweep(n, chunk, seed, Some(&full_j), &full_csv, None);
    assert_eq!(reference.evaluated, n);
    let want = std::fs::read(&full_csv).unwrap();

    let text = std::fs::read_to_string(&full_j).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    // run_start + 8 sample_blocks + env_stats + run_end
    assert_eq!(lines.len(), 3 + n.div_ceil(chunk), "journal record count");

    for cut in 0..=lines.len() {
        // kill -9 after `cut` whole records: prefix + torn half-record
        let mut prefix = String::new();
        for line in &lines[..cut] {
            prefix.push_str(line);
            prefix.push('\n');
        }
        prefix.push_str("{\"kind\":\"sample_blo");
        let cut_j = tmp(&format!("cut-{cut}.jsonl"));
        std::fs::write(&cut_j, &prefix).unwrap();

        let records = Journal::load(&cut_j).unwrap();
        let events = journal::sweep_events(&records);
        let cut_csv = tmp(&format!("cut-{cut}.csv"));
        let resumed = run_sweep(n, chunk, seed, None, &cut_csv, Some(&events));

        assert_eq!(
            resumed.resumed + resumed.evaluated,
            n,
            "cut at record {cut}: restored + fresh rows cover the design"
        );
        assert_eq!(
            std::fs::read(&cut_csv).unwrap(),
            want,
            "cut at record {cut}: resumed CSV must be byte-identical"
        );
        for p in [&cut_j, &cut_csv] {
            let _ = std::fs::remove_file(p);
        }
    }

    for p in [&full_j, &full_csv] {
        let _ = std::fs::remove_file(p);
    }
}
