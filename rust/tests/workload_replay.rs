//! Workload generator end-to-end: deterministic trace generation, an
//! in-process replay through broker + fair-share, and a remote replay
//! against a live `molers serve` daemon — which doubles as the proof
//! that the daemon's provenance manifests reexec byte-identically.
//!
//! `MOLERS_ARTIFACTS`/`MOLERS_SIM_TICKS` are pinned to the same values
//! the daemon is spawned with, so the in-process reexec at the end runs
//! the same evaluator the daemon did.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use molers::broker::RetryPolicy;
use molers::cli::Args;
use molers::util::json::Json;
use molers::workload::{replay_local, replay_remote, ReplayConfig, ReplaySummary, TraceSpec};

const SIM_TICKS: &str = "40";

fn pin_env() {
    std::env::set_var("MOLERS_ARTIFACTS", "/nonexistent-artifacts");
    std::env::set_var("MOLERS_SIM_TICKS", SIM_TICKS);
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("molers-wl-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn trace_generation_is_deterministic_and_on_spec() {
    let spec = TraceSpec::parse(
        "jobs=12;arrival=poisson:2;tenants=alice:3,bob:1;mix=explore:3,replicate:1;rows=16..64",
    )
    .unwrap();
    let a = spec.generate(9);
    let b = spec.generate(9);
    assert_eq!(a.to_jsonl(), b.to_jsonl(), "same seed → same trace");
    assert_ne!(
        a.to_jsonl(),
        spec.generate(10).to_jsonl(),
        "different seed → different trace"
    );
    assert_eq!(a.jobs.len(), 12);
    assert!(a.jobs.iter().all(|j| j.tenant == "alice" || j.tenant == "bob"));
    assert!(a.jobs.iter().all(|j| j.run == "explore" || j.run == "replicate"));
    let mut at = 0.0;
    for j in &a.jobs {
        assert!(j.at_s >= at, "release times are monotone");
        at = j.at_s;
    }
}

#[test]
fn local_replay_completes_every_job() {
    pin_env();
    let dir = tmp_dir("local");
    let spec =
        TraceSpec::parse("jobs=6;arrival=uniform:0;mix=explore:1;rows=16..32;chunk=8").unwrap();
    let trace = spec.generate(3);
    let cfg = ReplayConfig {
        lanes: 2,
        workdir: dir.clone(),
        ..ReplayConfig::default()
    };
    let records = replay_local(&trace, &cfg).unwrap();
    assert_eq!(records.len(), 6);
    for r in &records {
        assert!(r.ok, "job {} failed: {:?}", r.idx, r.error);
        assert!(r.evaluations > 0);
        assert!(r.done_s >= r.start_s);
    }
    let summary = ReplaySummary::from_records(&records).with_weights(&spec.tenants);
    assert_eq!((summary.jobs, summary.ok, summary.failed), (6, 6, 0));
    assert!(summary.fairness > 0.0 && summary.fairness <= 1.0 + 1e-12);
    assert!(summary.makespan_s > 0.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fault_overlay_still_completes_under_retries() {
    pin_env();
    let dir = tmp_dir("fault");
    let spec = TraceSpec::parse("jobs=4;mix=explore:1;rows=16..24;chunk=8").unwrap();
    let trace = spec.generate(5);
    let cfg = ReplayConfig {
        envs: "local:4,local:4".into(),
        fault: Some("drop=0.05".into()),
        lanes: 2,
        retry: RetryPolicy {
            backoff_base_s: 0.01,
            backoff_max_s: 0.05,
            ..RetryPolicy::default()
        },
        workdir: dir.clone(),
        ..ReplayConfig::default()
    };
    let records = replay_local(&trace, &cfg).unwrap();
    assert_eq!(records.iter().filter(|r| r.ok).count(), 4, "retries absorb drops");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------- daemon

/// A running daemon; killed on drop so a failing test never leaks it.
struct Daemon {
    child: Child,
    addr: String,
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn start_server(dir: &Path) -> Daemon {
    let addr_file = dir.join("addr");
    let _ = std::fs::remove_file(&addr_file);
    let child = Command::new(env!("CARGO_BIN_EXE_molers"))
        .env("MOLERS_ARTIFACTS", "/nonexistent-artifacts")
        .env("MOLERS_SIM_TICKS", SIM_TICKS)
        .args(["serve", "--addr", "127.0.0.1:0", "--state-dir"])
        .arg(dir)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn molers serve");
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Ok(text) = std::fs::read_to_string(&addr_file) {
            let addr = text.trim().to_string();
            if !addr.is_empty() && std::net::TcpStream::connect(&addr).is_ok() {
                break addr;
            }
        }
        assert!(Instant::now() < deadline, "daemon never came up");
        std::thread::sleep(Duration::from_millis(20));
    };
    Daemon { child, addr }
}

fn request(addr: &str, line: &str) -> Json {
    use std::io::{BufRead, BufReader, Write};
    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    writeln!(stream, "{line}").unwrap();
    let mut resp = String::new();
    BufReader::new(stream).read_line(&mut resp).unwrap();
    molers::util::json::parse(resp.trim_end()).expect("json response")
}

#[test]
fn remote_replay_drives_a_live_daemon_and_its_manifests_reexec() {
    pin_env();
    let dir = tmp_dir("remote");
    let daemon = start_server(&dir);

    let spec = TraceSpec::parse(
        "jobs=4;arrival=uniform:0;tenants=alice:2,bob:1;mix=explore:1;rows=16..32;chunk=8",
    )
    .unwrap();
    let trace = spec.generate(7);
    let records =
        replay_remote(&trace, &daemon.addr, 0.0, Duration::from_millis(50)).unwrap();
    assert_eq!(records.len(), 4);
    for r in &records {
        assert!(r.ok, "job {} failed: {:?}", r.idx, r.error);
        assert!(r.evaluations > 0);
    }

    // satellite: once terminal, status advertises the provenance manifest
    let status = request(&daemon.addr, "{\"cmd\":\"status\",\"id\":1}");
    let manifest = status
        .get("manifest")
        .and_then(Json::as_str)
        .expect("terminal explore status carries `manifest`")
        .to_string();
    assert!(Path::new(&manifest).exists(), "{manifest}");

    // acceptance: the daemon's manifest reexecs byte-identically in-process
    let args = Args::parse(["reexec".to_string(), manifest.clone()]).unwrap();
    let rep = molers::provenance::reexec(&manifest, &args).unwrap();
    assert_eq!(rep.run, "explore");
    assert!(rep.evaluations > 0);

    drop(daemon);
    let _ = std::fs::remove_dir_all(&dir);
}
