//! Oversubscription regression (§satellite): brokering several `local`
//! backends on one machine must share one worker pool. Before the fix,
//! each `LocalEnvironment::new` spun up a private pool, so a broker over
//! three `local:4` entries ran 12 worker threads on a 3-core budget.
//!
//! This test lives in its own integration binary on purpose: the live
//! worker count is process-global, and any concurrently running test
//! that creates a pool would make the assertions racy.

use std::sync::Arc;

use molers::broker::Broker;
use molers::core::Context;
use molers::dsl::ClosureTask;
use molers::environment::local::LocalEnvironment;
use molers::environment::{run_all, Environment, Job};
use molers::exec::ThreadPool;

#[test]
fn brokered_locals_share_one_pool() {
    let before = ThreadPool::live_workers();

    let shared = Arc::new(ThreadPool::new(3));
    assert_eq!(ThreadPool::live_workers(), before + 3);

    // three local backends brokered on this machine: still 3 workers
    let broker =
        Broker::from_spec("local:4,local:4,local:4", Arc::clone(&shared), 1).unwrap();
    assert_eq!(
        ThreadPool::live_workers(),
        before + 3,
        "brokered local backends must share the machine pool, not spawn private ones"
    );

    // the fleet actually runs work
    let task = Arc::new(ClosureTask::new("noop", |c: &Context| Ok(c.clone())));
    let results = run_all(
        &broker,
        (0..12)
            .map(|_| Job::new(Arc::clone(&task) as _, Context::new()))
            .collect(),
    );
    for r in results {
        r.unwrap();
    }
    assert_eq!(broker.stats().completed, 12);
    assert_eq!(
        ThreadPool::live_workers(),
        before + 3,
        "running brokered work must not grow the worker set"
    );

    // contrast: per-environment private pools do oversubscribe — this is
    // exactly what the broker path avoids
    let a = LocalEnvironment::new(4);
    let b = LocalEnvironment::new(4);
    assert_eq!(ThreadPool::live_workers(), before + 3 + 8);
    drop(a);
    drop(b);

    drop(broker);
    drop(shared);
    assert_eq!(
        ThreadPool::live_workers(),
        before,
        "all workers must be joined once pools are dropped"
    );
}
