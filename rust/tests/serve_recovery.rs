//! Power-loss harness for the serve layer (§Durable-by-construction
//! tentpole, part 4): the server's meta-journal (`server.jsonl`) and a
//! per-experiment checkpoint journal (`exp-N.jsonl`) are cut at **every
//! byte offset** — optionally with garbage welded onto the tail, exactly
//! what a power cut mid-`write(2)` leaves behind — and the recovery path
//! is driven over each wreck.
//!
//! The contract under test:
//!
//! * `Registry::open` never errors on a torn journal: it recovers every
//!   record whose line made it to disk in full (= every record the
//!   daemon *acknowledged* under `--durability always`), including
//!   terminal states and dedup keys, and keeps allocating ids past the
//!   recovered maximum.
//! * An explore whose checkpoint journal was cut at any byte resumes to
//!   a result file **byte-identical** to the uninterrupted reference run
//!   (extending `chaos_recovery.rs` from record-boundary cuts to
//!   arbitrary byte cuts, through the same `--resume` front the serve
//!   scheduler uses after a restart).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use molers::cli::{front, Args};
use molers::serve::{ExpState, Registry};
use molers::util::json::{self, Json};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-srv-recov-{}-{name}", std::process::id()))
}

/// In debug builds stride the byte offsets so `cargo test` stays quick;
/// release CI walks every single byte.
fn stride() -> usize {
    if cfg!(debug_assertions) {
        13
    } else {
        1
    }
}

/// Every cut offset: strided interior points plus both endpoints.
fn cuts(len: usize) -> Vec<usize> {
    let mut cs: Vec<usize> = (0..=len).step_by(stride()).collect();
    if cs.last() != Some(&len) {
        cs.push(len);
    }
    cs
}

/// Fold a (possibly torn) meta-journal the way `Registry` replay does:
/// lossy decode, every complete line applies, a final line that fails to
/// parse is the torn tail and is dropped. Returns `(id -> state,
/// (tenant, dedup_key) -> id)`.
#[allow(clippy::type_complexity)]
fn fold_expected(
    bytes: &[u8],
) -> (BTreeMap<u64, String>, BTreeMap<(String, String), u64>) {
    let text = String::from_utf8_lossy(bytes);
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    let mut states: BTreeMap<u64, String> = BTreeMap::new();
    let mut dedup: BTreeMap<(String, String), u64> = BTreeMap::new();
    for (i, line) in lines.iter().enumerate() {
        let Ok(rec) = json::parse(line) else {
            assert_eq!(
                i + 1,
                lines.len(),
                "a cut can only tear the final journal line"
            );
            break;
        };
        let id = rec.get("id").and_then(Json::as_f64).unwrap() as u64;
        match rec.get("kind").and_then(Json::as_str) {
            Some("exp") => {
                states.insert(id, "queued".to_string());
                if let Some(k) = rec.get("dedup_key").and_then(Json::as_str) {
                    let tenant = rec
                        .get("tenant")
                        .and_then(Json::as_str)
                        .unwrap()
                        .to_string();
                    dedup.insert((tenant, k.to_string()), id);
                }
            }
            Some("exp_state") => {
                let s = rec.get("state").and_then(Json::as_str).unwrap();
                states.insert(id, s.to_string());
            }
            _ => panic!("unexpected record kind in {line}"),
        }
    }
    (states, dedup)
}

/// Tails a power cut can weld onto the last sector: nothing, a torn
/// half-record, NUL padding, and raw non-UTF-8 garbage.
const TAILS: &[&[u8]] = &[
    b"",
    b"{\"kind\":\"exp\",\"id\":9,\"tena",
    b"\x00\x00\x00\x00\x00\x00",
    b"\xff\xfe\x00\xffgarbage\xff",
];

#[test]
fn meta_journal_recovers_at_every_byte_cut_with_any_tail() {
    // reference daemon life: three submissions (two with dedup keys),
    // two of them reaching terminal states — all under the server's
    // default fsync-per-record durability
    let ref_dir = tmp("meta-ref");
    let _ = std::fs::remove_dir_all(&ref_dir);
    {
        let reg = Registry::open(&ref_dir).unwrap();
        let (a, fresh) = reg
            .submit(
                "alice",
                2,
                "explore",
                vec!["explore".into(), "--n".into(), "9".into()],
                Some("k-alpha"),
            )
            .unwrap();
        assert!(fresh);
        let (b, _) = reg
            .submit("bob", 1, "calibrate", vec!["calibrate".into()], None)
            .unwrap();
        let (c, _) = reg
            .submit("carol", 1, "run", vec!["run".into()], Some("k-carol"))
            .unwrap();
        assert_eq!((a, b, c), (1, 2, 3));
        reg.set_running(a);
        reg.set_running(b);
        reg.finish(a, ExpState::Done, None, Some(Json::Num(9.0))).unwrap();
        reg.finish(b, ExpState::Failed, Some("boom".into()), None).unwrap();
    }
    let bytes = std::fs::read(ref_dir.join("server.jsonl")).unwrap();
    assert_eq!(
        String::from_utf8_lossy(&bytes).lines().count(),
        5,
        "3 exp + 2 exp_state records"
    );

    let scratch = tmp("meta-cut");
    for cut in cuts(bytes.len()) {
        for tail in TAILS {
            let mut wreck = bytes[..cut].to_vec();
            wreck.extend_from_slice(tail);
            let (states, dedup) = fold_expected(&wreck);

            let _ = std::fs::remove_dir_all(&scratch);
            std::fs::create_dir_all(&scratch).unwrap();
            std::fs::write(scratch.join("server.jsonl"), &wreck).unwrap();

            // recovery must never error, whatever the wreck looks like
            let reg = Registry::open(&scratch)
                .unwrap_or_else(|e| panic!("cut {cut} tail {tail:?}: open failed: {e}"));
            let got: BTreeMap<u64, String> = reg
                .list()
                .iter()
                .map(|r| (r.id, r.state.as_str().to_string()))
                .collect();
            assert_eq!(
                got, states,
                "cut {cut} tail {tail:?}: recovered table != complete-line fold"
            );
            for ((tenant, key), id) in &dedup {
                assert_eq!(
                    reg.dedup_lookup(tenant, key),
                    Some(*id),
                    "cut {cut}: journaled dedup key survives the crash"
                );
            }
            assert_eq!(reg.dedup_lookup("alice", "never-submitted"), None);

            // ids keep climbing past everything recovered — and the
            // repaired journal accepts new durable appends
            let expect_next = states.keys().max().copied().unwrap_or(0) + 1;
            let (next, fresh) = reg
                .submit("probe", 1, "run", vec!["run".into()], None)
                .unwrap();
            assert!(fresh);
            assert_eq!(next, expect_next, "cut {cut} tail {tail:?}");
        }
    }

    // the untouched full journal recovers error + summary verbatim
    let reg = Registry::open(&ref_dir).unwrap();
    assert_eq!(reg.get(1).unwrap().state, ExpState::Done);
    assert_eq!(reg.get(1).unwrap().summary, Some(Json::Num(9.0)));
    assert_eq!(reg.get(2).unwrap().state, ExpState::Failed);
    assert_eq!(reg.get(2).unwrap().error.as_deref(), Some("boom"));
    assert_eq!(reg.get(3).unwrap().state, ExpState::Queued);

    for d in [&ref_dir, &scratch] {
        let _ = std::fs::remove_dir_all(d);
    }
}

fn explore_args(out: &Path, journal_flag: &str, jpath: &Path) -> Args {
    let argv = [
        "explore",
        "--n",
        "6",
        "--chunk",
        "2",
        "--sampling",
        "sobol",
        "--seed",
        "11",
        journal_flag,
        &jpath.to_string_lossy(),
        "--out",
        &out.to_string_lossy(),
        "--durability",
        "always",
    ];
    Args::parse(argv.iter().map(|s| s.to_string())).unwrap()
}

#[test]
fn explore_journal_resumes_byte_identically_from_every_byte_cut() {
    // keep the per-row model tiny: this test's cost is cuts × resumes
    std::env::set_var("MOLERS_SIM_TICKS", "10");
    std::env::set_var("MOLERS_ARTIFACTS", "/nonexistent-artifacts");

    let ref_csv = tmp("exp-ref.csv");
    let ref_j = tmp("exp-ref.jsonl");
    for p in [&ref_csv, &ref_j] {
        let _ = std::fs::remove_file(p);
    }
    // uninterrupted reference under fsync-per-record durability — the
    // same `--journal`/`--durability` argv the serve scheduler builds
    let report = front::explore(&explore_args(&ref_csv, "--journal", &ref_j))
        .unwrap()
        .quiet()
        .run()
        .unwrap();
    assert_eq!(report.outcome.rows, 6);
    let want = std::fs::read(&ref_csv).unwrap();
    let bytes = std::fs::read(&ref_j).unwrap();
    assert!(
        String::from_utf8_lossy(&bytes).lines().count() >= 5,
        "run_start + 3 sample blocks + trailer records"
    );

    let cut_csv = tmp("exp-cut.csv");
    let cut_j = tmp("exp-cut.jsonl");
    for cut in cuts(bytes.len()) {
        // alternate the welded tail so both pure truncation and a
        // garbage sector are exercised at interleaved offsets
        let tail: &[u8] = if cut % 2 == 0 { b"" } else { b"{\"kind\":\"sa\x00\xff" };
        let mut wreck = bytes[..cut].to_vec();
        wreck.extend_from_slice(tail);
        std::fs::write(&cut_j, &wreck).unwrap();
        let _ = std::fs::remove_file(&cut_csv);

        let resumed = front::explore(&explore_args(&cut_csv, "--resume", &cut_j))
            .unwrap()
            .quiet()
            .run()
            .unwrap_or_else(|e| panic!("cut {cut}: resume failed: {e}"));
        assert_eq!(
            resumed.outcome.resumed + resumed.outcome.evaluated,
            6,
            "cut {cut}: restored + fresh rows cover the design"
        );
        assert_eq!(
            std::fs::read(&cut_csv).unwrap(),
            want,
            "cut {cut}: resumed CSV must be byte-identical to the reference"
        );
    }

    for p in [&ref_csv, &ref_j, &cut_csv, &cut_j] {
        let _ = std::fs::remove_file(p);
    }
}
