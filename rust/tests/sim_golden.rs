//! Golden determinism tests for the §Perf hot-path refactor.
//!
//! `sim::reference` is the pre-optimisation kernel kept verbatim (fresh
//! `vec!` per diffuse, cloned ant per tick, full-grid latch scans). The
//! optimised `sim::ants` must reproduce its trajectories **bit for bit**:
//! same RNG draw order, same IEEE-754 operation order, same latch ticks.
//! Any divergence — however small — means the refactor changed model
//! behaviour, not just its cost.

use molers::sim::ants::{evaluate, AntParams, AntSim, WORLD};
use molers::sim::reference::{evaluate as reference_evaluate, ReferenceAntSim};

const GOLDEN_SEEDS: [u64; 3] = [1, 42, 0xDEAD_BEEF];

fn paper_defaults() -> AntParams {
    AntParams {
        population: 125.0,
        diffusion_rate: 50.0,
        evaporation_rate: 50.0,
    }
}

fn trail_params() -> AntParams {
    // low evaporation: persistent trails, all sources empty within the run
    AntParams {
        population: 125.0,
        diffusion_rate: 50.0,
        evaporation_rate: 10.0,
    }
}

#[test]
fn golden_objectives_bit_identical_across_seeds() {
    for &seed in &GOLDEN_SEEDS {
        for params in [paper_defaults(), trail_params()] {
            let optimised = evaluate(params, seed, 600);
            let reference = reference_evaluate(params, seed, 600);
            for o in 0..3 {
                assert_eq!(
                    optimised[o].to_bits(),
                    reference[o].to_bits(),
                    "objective {o} diverged for seed {seed} / {params:?}: \
                     optimised {optimised:?} vs reference {reference:?}"
                );
            }
        }
    }
}

#[test]
fn golden_full_state_bit_identical_after_stepping() {
    // stronger than the objective check: every patch of every field and
    // every ant pose must match after 250 interleaved ticks
    let seed = GOLDEN_SEEDS[1];
    let mut fast = AntSim::new(trail_params(), seed);
    let mut slow = ReferenceAntSim::new(trail_params(), seed);
    for _ in 0..250 {
        fast.step();
        slow.step();
    }
    assert_eq!(fast.tick, slow.tick);
    assert_eq!(fast.final_ticks, slow.final_ticks);
    for r in 0..WORLD {
        for c in 0..WORLD {
            assert_eq!(
                fast.chemical.get(r, c).to_bits(),
                slow.chemical.get(r, c).to_bits(),
                "chemical diverged at ({r}, {c})"
            );
            assert_eq!(
                fast.food.get(r, c).to_bits(),
                slow.food.get(r, c).to_bits(),
                "food diverged at ({r}, {c})"
            );
        }
    }
    let (fp, sp) = (fast.ant_positions(), slow.ant_positions());
    assert_eq!(fp.len(), sp.len());
    for (i, (a, b)) in fp.iter().zip(&sp).enumerate() {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "ant {i} x diverged");
        assert_eq!(a.1.to_bits(), b.1.to_bits(), "ant {i} y diverged");
        assert_eq!(a.2, b.2, "ant {i} carrying diverged");
    }
    // and the incremental counters equal the reference's grid scans
    let (fr, sr) = (fast.remaining(), slow.remaining());
    for s in 0..3 {
        assert_eq!(fr[s].to_bits(), sr[s].to_bits(), "source {s} remaining");
    }
}

#[test]
fn golden_zero_population_edge_case() {
    let params = AntParams {
        population: 0.0,
        ..trail_params()
    };
    for &seed in &GOLDEN_SEEDS {
        assert_eq!(
            evaluate(params, seed, 100),
            reference_evaluate(params, seed, 100)
        );
    }
}
