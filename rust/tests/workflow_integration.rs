//! Integration: the workflow engine over simulated distributed
//! environments — the paper's Listings 2/3 shapes end to end.

use std::sync::Arc;

use molers::environment::cluster::{BatchEnvironment, InfraModel};
use molers::environment::egi::EgiEnvironment;
use molers::environment::ssh::SshEnvironment;
use molers::exec::ThreadPool;
use molers::prelude::*;
use molers::sim::{evaluate, AntParams};

fn ant_task(
    seed: &Val<u32>,
    food: &[Val<f64>; 3],
    max_ticks: u32,
) -> Arc<ClosureTask> {
    let (s, f) = (seed.clone(), food.clone());
    let (s2, f2) = (seed.clone(), food.clone());
    Arc::new(
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit = evaluate(
                AntParams {
                    population: 125.0,
                    diffusion_rate: 50.0,
                    evaporation_rate: 10.0,
                },
                u64::from(ctx.get(&s)?),
                max_ticks,
            );
            let mut out = Context::new();
            for (fv, v) in f.iter().zip(fit) {
                out.set(fv, v);
            }
            Ok(out)
        })
        .input(&s2)
        .output(&f2[0])
        .output(&f2[1])
        .output(&f2[2])
        .cost(36.0),
    )
}

#[test]
fn listing3_replication_on_slurm_cluster() {
    // the full Listing 3 workflow, but the model capsule delegated to a
    // simulated Slurm cluster (the §2.2 one-line switch)
    let seed = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let med = [val_f64("med1"), val_f64("med2"), val_f64("med3")];
    let mut stat = StatisticTask::new();
    for (f, m) in food.iter().zip(&med) {
        stat = stat.statistic(f, m, Descriptor::Median);
    }
    let b = PuzzleBuilder::new();
    let (_, model_c, _) = replicate(
        &b,
        ant_task(&seed, &food, 150) as Arc<dyn Task>,
        &seed,
        5,
        Arc::new(stat),
    );
    let pool = Arc::new(ThreadPool::new(4));
    let slurm = Arc::new(BatchEnvironment::slurm(4, pool, 9));
    let capture = Arc::new(CaptureHook::new());
    model_c.on(slurm.clone()).hook(capture.clone());

    let result = MoleExecution::new(
        b.build().unwrap(),
        Arc::new(LocalEnvironment::new(2)),
        42,
    )
    .start()
    .unwrap();

    assert_eq!(result.outputs.len(), 1);
    assert_eq!(capture.len(), 5, "five replications ran");
    let m1 = result.outputs[0].get(&med[0]).unwrap();
    assert!(m1 > 0.0 && m1 <= 150.0);
    // the five model jobs went through the cluster, not the local env
    assert_eq!(slurm.stats().completed, 5);
    // cluster virtual time includes 5 x 36 s of work on 4 nodes
    assert!(result.report.virtual_makespan >= 36.0 * 2.0 - 1e-6);
}

#[test]
fn doe_fanout_on_egi_with_failures() {
    // full-factorial exploration delegated to a flaky grid: every sample
    // must still come back exactly once (resubmission machinery)
    let x = val_f64("x");
    let y = val_f64("y");
    let task = Arc::new(
        ClosureTask::new("sq", {
            let (x, y) = (x.clone(), y.clone());
            move |ctx: &Context| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
        })
        .input(&x)
        .output(&y)
        .cost(10.0),
    );
    let pool = Arc::new(ThreadPool::new(4));
    let egi = Arc::new(
        EgiEnvironment::new("biomed", 8, pool, 17).with_infra(InfraModel {
            failure_rate: 0.3,
            max_retries: 10,
            ..InfraModel::grid()
        }),
    );

    let b = PuzzleBuilder::new();
    let entry = b.task(IdentityTask::new("entry"));
    let model = b.capsule(task);
    let agg = b.task(IdentityTask::new("agg"));
    entry.explore(
        Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 15.0, 1.0)])),
        &model,
    );
    model.aggregate(&agg);
    model.on(egi.clone());

    let result = MoleExecution::new(
        b.build().unwrap(),
        Arc::new(LocalEnvironment::new(2)),
        3,
    )
    .start()
    .unwrap();
    let mut ys: Vec<f64> = result.outputs[0].get(&y.array()).unwrap();
    ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let want: Vec<f64> = (0..16).map(|i| f64::from(i * i)).collect();
    assert_eq!(ys, want, "every sample returned exactly once despite failures");
    assert!(egi.stats().resubmissions > 0, "failures were injected");
}

#[test]
fn ssh_and_local_agree_on_results() {
    // same workflow, two environments: numerical results identical
    let seed = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let run = |env: Arc<dyn Environment>| -> Vec<f64> {
        let b = PuzzleBuilder::new();
        let c = b.capsule(ant_task(&seed, &food, 120) as Arc<dyn Task>);
        c.on(env);
        let init = Context::new().with(&seed, 77);
        let r = MoleExecution::new(
            b.build_with(&init).unwrap(),
            Arc::new(LocalEnvironment::new(1)),
            5,
        )
        .start_with(init)
        .unwrap();
        food.iter().map(|f| r.outputs[0].get(f).unwrap()).collect()
    };
    let pool = Arc::new(ThreadPool::new(2));
    let local = run(Arc::new(LocalEnvironment::new(2)));
    let ssh = run(Arc::new(SshEnvironment::new("calc01", 2, pool, 1)));
    assert_eq!(local, ssh, "environment choice must not change results");
}

#[test]
fn csv_hook_records_exploration() {
    let dir = std::env::temp_dir().join(format!("molers-it-{}", std::process::id()));
    let path = dir.join("doe.csv");
    let _ = std::fs::remove_file(&path);
    let x = val_f64("x");
    let task = Arc::new(
        ClosureTask::new("id", {
            let x = x.clone();
            move |ctx: &Context| Ok(Context::new().with(&x, ctx.get(&x)?))
        })
        .input(&x)
        .output(&x),
    );
    let b = PuzzleBuilder::new();
    let entry = b.task(IdentityTask::new("entry"));
    let model = b.capsule(task);
    entry.explore(
        Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 4.0, 1.0)])),
        &model,
    );
    model.hook(Arc::new(CsvHook::new(&path, &["x"])));
    MoleExecution::new(b.build().unwrap(), Arc::new(LocalEnvironment::new(2)), 1)
        .start()
        .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 6); // header + 5 samples
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn virtual_time_chains_through_transitions() {
    // a -> b on a cluster: b's virtual start must be after a's end
    let pool = Arc::new(ThreadPool::new(2));
    let pbs = Arc::new(BatchEnvironment::pbs(2, pool, 31));
    let t = |name: &str| -> Arc<dyn Task> {
        Arc::new(
            ClosureTask::new(name.to_string(), |ctx: &Context| Ok(ctx.clone())).cost(20.0),
        )
    };
    let builder = PuzzleBuilder::new();
    let a = builder.capsule(t("a"));
    let b = builder.capsule(t("b"));
    a.then(&b);
    a.on(pbs.clone());
    b.on(pbs.clone());
    let r = MoleExecution::new(
        builder.build().unwrap(),
        Arc::new(LocalEnvironment::new(1)),
        2,
    )
    .start()
    .unwrap();
    // two 20 s jobs chained: makespan >= 40 s plus latencies
    assert!(
        r.report.virtual_makespan >= 40.0,
        "b must queue after a: {}",
        r.report.virtual_makespan
    );
}

#[test]
fn sources_inject_before_each_run() {
    use molers::dsl::{ConstantSource, CsvSource};
    // CSV source feeds an array; constant source feeds a scalar; the task
    // consumes both
    let dir = std::env::temp_dir().join(format!("molers-src-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("input.csv");
    std::fs::write(&csv, "obs\n10\n20\n30\n").unwrap();

    let obs = val_f64("obs");
    let scale = val_f64("scale");
    let total = val_f64("total");
    let task = Arc::new(
        ClosureTask::new("sum", {
            let (obs, scale, total) = (obs.clone(), scale.clone(), total.clone());
            move |ctx: &Context| {
                let xs: Vec<f64> = ctx.get(&obs.array())?;
                let k = ctx.get(&scale)?;
                Ok(Context::new().with(&total, xs.iter().sum::<f64>() * k))
            }
        })
        .output(&total),
    );
    let b = PuzzleBuilder::new();
    let c = b.capsule(task);
    c.source(Arc::new(CsvSource::new(&csv, &["obs"])))
        .source(Arc::new(ConstantSource::new().with(&scale, 2.0)));
    let r = MoleExecution::new(b.build().unwrap(), Arc::new(LocalEnvironment::new(1)), 1)
        .start()
        .unwrap();
    assert_eq!(r.outputs[0].get(&total).unwrap(), 120.0);
    let _ = std::fs::remove_dir_all(&dir);
}
