//! Kill-mid-sweep + `--resume` integration (§Exploration acceptance): a
//! sweep killed partway — simulated by truncating its journal to a prefix
//! of `sample_block` checkpoints and tearing the final line, exactly what
//! a `kill -9` leaves behind — must resume over a failing broker, skip the
//! checkpointed rows, and produce a **byte-identical** result file.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use molers::broker::{journal, Broker, Journal};
use molers::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};
use molers::exec::ThreadPool;
use molers::exploration::{row_seed, LhsSampling, Sampling, Sweep};
use molers::prelude::*;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-explore-{}-{name}", std::process::id()))
}

fn sampling(n: usize) -> Arc<dyn Sampling> {
    let x = val_f64("x0");
    let y = val_f64("x1");
    Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n))
}

/// A broker over one healthy and one 50%-failing local backend: every
/// chunk survives via re-routing, as in the acceptance scenario.
fn flaky_broker(seed: u64) -> Broker {
    let pool = Arc::new(ThreadPool::new(2));
    Broker::from_spec("local:2,local:2~0.5", pool, seed).unwrap()
}

/// Simulate `kill -9`: keep the journal's `run_start` plus the first
/// `keep_blocks` checkpoints, then a torn half-written line.
fn killed_journal(full: &Path, cut: &Path, keep_blocks: usize) -> usize {
    let text = std::fs::read_to_string(full).unwrap();
    let mut out = String::new();
    let mut kept_rows = 0;
    let mut blocks = 0;
    for line in text.lines() {
        let is_block = line.contains("\"kind\":\"sample_block\"");
        if is_block && blocks >= keep_blocks {
            continue;
        }
        if line.contains("\"kind\":\"env_stats\"") || line.contains("\"kind\":\"run_end\"") {
            continue;
        }
        if is_block {
            blocks += 1;
            let rec = molers::util::json::parse(line).unwrap();
            kept_rows += rec.get("rows").unwrap().as_usize().unwrap();
        }
        out.push_str(line);
        out.push('\n');
    }
    out.push_str("{\"kind\":\"sample_blo"); // torn mid-write
    std::fs::write(cut, out).unwrap();
    kept_rows
}

fn run_sweep(
    n: usize,
    chunk: usize,
    seed: u64,
    journal_path: &Path,
    out_path: &Path,
    format: TableFormat,
    resume: Option<&[journal::SweepEvent]>,
) -> molers::exploration::SweepResult {
    let columns = ["x0", "x1", "f1", "f2"];
    let writer = Arc::new(RowWriter::create(out_path, format, &columns).unwrap());
    let j = if resume.is_some() {
        Journal::append_to(journal_path).unwrap()
    } else {
        Journal::create(journal_path).unwrap()
    };
    let env = flaky_broker(seed ^ 0xB10C);
    Sweep::new(sampling(n), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
        .chunk(chunk)
        .journal(Arc::new(j))
        .writer(writer)
        .run_resumable(&env, seed, resume)
        .unwrap()
}

#[test]
fn kill_and_resume_reaches_byte_identical_csv() {
    let (n, chunk, seed) = (60, 8, 7u64);
    let full_j = tmp("full.jsonl");
    let full_csv = tmp("full.csv");
    let cut_j = tmp("cut.jsonl");
    let cut_csv = tmp("cut.csv");

    // uninterrupted reference run, through a failing broker
    let full = run_sweep(n, chunk, seed, &full_j, &full_csv, TableFormat::Csv, None);
    assert_eq!(full.evaluated, n);
    let want = std::fs::read(&full_csv).unwrap();
    assert_eq!(
        want.iter().filter(|&&b| b == b'\n').count(),
        n + 1,
        "header + one row per sample"
    );

    // kill after 3 checkpointed blocks (completion order — possibly
    // including the short tail block), torn final line included
    let kept_rows = killed_journal(&full_j, &cut_j, 3);
    assert!(kept_rows > 0 && kept_rows < n);

    // resume: restored rows are not re-evaluated...
    let records = Journal::load(&cut_j).unwrap();
    let events = journal::sweep_events(&records);
    assert_eq!(events.len(), 3);
    let resumed = run_sweep(
        n,
        chunk,
        seed,
        &cut_j,
        &cut_csv,
        TableFormat::Csv,
        Some(&events),
    );
    assert_eq!(resumed.resumed, kept_rows);
    assert_eq!(resumed.evaluated, n - kept_rows);

    // ...and the result file is byte-identical to the uninterrupted run's
    let got = std::fs::read(&cut_csv).unwrap();
    assert_eq!(got, want, "resumed CSV must be byte-identical");

    // the resumed journal is whole again: torn tail repaired, all blocks
    // loadable, run_end present
    let records = Journal::load(&cut_j).unwrap();
    let total_rows: usize = journal::sample_blocks(&records)
        .iter()
        .map(|b| b.objectives.len())
        .sum();
    assert_eq!(total_rows, n, "old + new checkpoints cover the design");
    assert!(records
        .iter()
        .any(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_end")));

    for p in [&full_j, &full_csv, &cut_j, &cut_csv] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn kill_and_resume_reaches_byte_identical_jsonl() {
    let (n, chunk, seed) = (30, 5, 11u64);
    let full_j = tmp("fullj.jsonl");
    let full_out = tmp("full-rows.jsonl");
    let cut_j = tmp("cutj.jsonl");
    let cut_out = tmp("cut-rows.jsonl");

    run_sweep(n, chunk, seed, &full_j, &full_out, TableFormat::Jsonl, None);
    killed_journal(&full_j, &cut_j, 2);
    let events = journal::sweep_events(&Journal::load(&cut_j).unwrap());
    run_sweep(
        n,
        chunk,
        seed,
        &cut_j,
        &cut_out,
        TableFormat::Jsonl,
        Some(&events),
    );
    assert_eq!(
        std::fs::read(&cut_out).unwrap(),
        std::fs::read(&full_out).unwrap(),
        "resumed JSONL must be byte-identical"
    );
    for p in [&full_j, &full_out, &cut_j, &cut_out] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn resumed_rows_are_never_reevaluated_and_seeds_are_positional() {
    // per-row seeds are a pure function of (sweep seed, row): any subset
    // re-evaluated on any backend reproduces the same objectives
    assert_eq!(row_seed(42, 7), row_seed(42, 7));
    assert_ne!(row_seed(42, 7), row_seed(42, 8));
    assert_ne!(row_seed(42, 7), row_seed(43, 7));

    let (n, chunk, seed) = (40, 10, 3u64);
    let full_j = tmp("count-full.jsonl");
    let full_csv = tmp("count-full.csv");
    let full = run_sweep(n, chunk, seed, &full_j, &full_csv, TableFormat::Csv, None);

    let cut_j = tmp("count-cut.jsonl");
    let kept = killed_journal(&full_j, &cut_j, 2);
    let events = journal::sweep_events(&Journal::load(&cut_j).unwrap());

    let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
    let env = LocalEnvironment::new(2);
    let resumed = Sweep::new(sampling(n), Arc::clone(&counting) as _, &["f1", "f2"])
        .chunk(chunk)
        .run_resumable(&env, seed, Some(&events))
        .unwrap();
    assert_eq!(counting.count() as usize, n - kept);
    assert_eq!(resumed.objectives, full.objectives);

    for p in [&full_j, &full_csv, &cut_j] {
        let _ = std::fs::remove_file(p);
    }
}
