//! Golden equivalence: each rebuilt subcommand path (MoleDSL v2
//! `Experiment` + `ExplorationMethod`) must produce **byte-identical**
//! journals and result files to the direct PR-2/PR-4 engine paths it
//! replaced. Runs use a simulated cluster over a single-worker pool, so
//! virtual clocks and record order are deterministic.

use std::path::PathBuf;
use std::sync::Arc;

use molers::broker::Journal;
use molers::environment::cluster::BatchEnvironment;
use molers::evolution::{
    AntSimEvaluator, Evaluator, GenerationalGA, IslandConfig, IslandSteadyGA,
    Individual, Nsga2Config, Zdt1Evaluator,
};
use molers::exec::ThreadPool;
use molers::prelude::*;
use molers::util::json::Json;
use molers::workflow::single_environment;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-eq-{}-{name}", std::process::id()))
}

/// Deterministic environment: simulated PBS (virtual time from cost
/// hints + a seeded infra model) over ONE pool worker (sequential
/// execution ⇒ deterministic completion order).
fn det_env(seed: u64) -> Arc<dyn Environment> {
    Arc::new(BatchEnvironment::pbs(2, Arc::new(ThreadPool::new(1)), seed))
}

fn lhs2(n: usize) -> Arc<dyn Sampling> {
    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    Arc::new(LhsSampling::new(&[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], n))
}

fn explore_method(out: &std::path::Path, n: usize) -> DirectSampling {
    DirectSampling {
        sampling: lhs2(n),
        evaluator: Arc::new(Zdt1Evaluator { dim: 2 }),
        kind: "zdt1".into(),
        design_columns: vec!["x0".into(), "x1".into()],
        objective_names: vec!["f1".into(), "f2".into()],
        chunk: 6,
        out_path: out.to_string_lossy().into_owned(),
        format: TableFormat::Csv,
        meta: vec![
            ("lo".into(), Json::Num(0.0)),
            ("hi".into(), Json::Num(1.0)),
            ("replications".into(), Json::Num(1.0)),
        ],
        degraded_ok: false,
        retry_degraded: false,
        mem_budget: None,
        spill_dir: None,
    }
}

fn read(path: &std::path::Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path:?}: {e}"))
}

fn front_of(front: &[Individual]) -> Vec<(Vec<f64>, Vec<f64>)> {
    front
        .iter()
        .map(|i| (i.genome.clone(), i.objectives.clone()))
        .collect()
}

#[test]
fn explore_experiment_matches_direct_sweep_byte_for_byte() {
    let (csv_a, j_a) = (tmp("swp-a.csv"), tmp("swp-a.jsonl"));
    let (csv_b, j_b) = (tmp("swp-b.csv"), tmp("swp-b.jsonl"));

    // the direct PR-4 path
    let writer = Arc::new(
        RowWriter::create(&csv_a, TableFormat::Csv, &["x0", "x1", "f1", "f2"]).unwrap(),
    );
    Sweep::new(lhs2(20), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
        .chunk(6)
        .writer(writer)
        .meta("lo", Json::Num(0.0))
        .meta("hi", Json::Num(1.0))
        .meta("replications", Json::Num(1.0))
        .journal(Arc::new(Journal::create(&j_a).unwrap()))
        .run(det_env(5).as_ref(), 42)
        .unwrap();

    // the same design through the Experiment front
    Experiment::new(Box::new(explore_method(&csv_b, 20)))
        .on(det_env(5))
        .seed(42)
        .journal(j_b.to_string_lossy().into_owned())
        .quiet()
        .run()
        .unwrap();

    assert_eq!(read(&csv_a), read(&csv_b), "result files must be byte-identical");
    assert_eq!(read(&j_a), read(&j_b), "journals must be byte-identical");
    for p in [&csv_a, &j_a, &csv_b, &j_b] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn explore_resume_through_experiment_reproduces_the_result_file() {
    let (csv_a, j_a) = (tmp("res-a.csv"), tmp("res-a.jsonl"));
    let csv_b = tmp("res-b.csv");

    // full run with a journal...
    Experiment::new(Box::new(explore_method(&csv_a, 18)))
        .on(det_env(9))
        .seed(7)
        .journal(j_a.to_string_lossy().into_owned())
        .quiet()
        .run()
        .unwrap();
    // ...then a resume from that (complete) journal into a fresh output:
    // every row restores from sample_block checkpoints, nothing
    // re-evaluates, and the file is byte-identical
    let report = Experiment::new(Box::new(explore_method(&csv_b, 18)))
        .on(det_env(9))
        .seed(7)
        .resume(j_a.to_string_lossy().into_owned())
        .quiet()
        .run()
        .unwrap();
    assert_eq!(report.outcome.resumed, 18);
    assert_eq!(report.outcome.evaluated, 0);
    assert_eq!(read(&csv_a), read(&csv_b), "resumed result must be byte-identical");
    for p in [&csv_a, &j_a, &csv_b] {
        let _ = std::fs::remove_file(p);
    }
}

fn zdt_config(mu: usize) -> Nsga2Config {
    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    let x2 = val_f64("x2");
    let f1 = val_f64("f1");
    let f2 = val_f64("f2");
    Nsga2Config::new(
        mu,
        &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
        &[&f1, &f2],
        0.1,
    )
    .unwrap()
}

#[test]
fn calibrate_experiment_matches_direct_ga_byte_for_byte() {
    let j_a = tmp("cal-a.jsonl");
    let j_b = tmp("cal-b.jsonl");

    // the direct PR-2/PR-3 path
    let direct = GenerationalGA::new(zdt_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
        .journal(Arc::new(Journal::create(&j_a).unwrap()))
        .run(det_env(3).as_ref(), 4, 11)
        .unwrap();

    // the same calibration through the Experiment front
    let report = Experiment::new(Box::new(Nsga2Evolution {
        config: zdt_config(8),
        lambda: 8,
        generations: 4,
        eval_chunk: 1,
        evaluator: Arc::new(Zdt1Evaluator { dim: 3 }),
        kind: "zdt1".into(),
        on_generation: None,
    }))
    .on(det_env(3))
    .seed(11)
    .journal(j_b.to_string_lossy().into_owned())
    .quiet()
    .run()
    .unwrap();

    assert_eq!(
        front_of(&direct.pareto_front),
        front_of(&report.outcome.pareto_front),
        "identical Pareto fronts"
    );
    assert_eq!(read(&j_a), read(&j_b), "journals must be byte-identical");
    for p in [&j_a, &j_b] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn island_experiment_matches_direct_ga_byte_for_byte() {
    let j_a = tmp("isl-a.jsonl");
    let j_b = tmp("isl-b.jsonl");
    let islands = IslandConfig {
        concurrent_islands: 4,
        total_evaluations: 64,
        island_sample: 8,
        evals_per_island: 16,
    };

    let direct = IslandSteadyGA::new(
        zdt_config(16),
        islands.clone(),
        Arc::new(Zdt1Evaluator { dim: 3 }),
    )
    .journal(Arc::new(Journal::create(&j_a).unwrap()))
    .run(det_env(8).as_ref(), 21, None)
    .unwrap();

    let report = Experiment::new(Box::new(IslandEvolution {
        config: zdt_config(16),
        islands,
        evaluator: Arc::new(Zdt1Evaluator { dim: 3 }),
        kind: "zdt1".into(),
        on_island: None,
    }))
    .on(det_env(8))
    .seed(21)
    .journal(j_b.to_string_lossy().into_owned())
    .quiet()
    .run()
    .unwrap();

    assert_eq!(
        front_of(&direct.pareto_front),
        front_of(&report.outcome.pareto_front)
    );
    assert_eq!(read(&j_a), read(&j_b), "journals must be byte-identical");
    for p in [&j_a, &j_b] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn replicate_experiment_matches_direct_puzzle() {
    let seed = val_u32("seed");
    let out = val_f64("out");
    let med = val_f64("med");
    let model = || {
        let (s, o) = (seed.clone(), out.clone());
        Arc::new(
            ClosureTask::new("m", move |ctx: &Context| {
                let v = ctx.get(&s)?;
                Ok(Context::new().with(&o, f64::from(v % 13)))
            })
            .input(&seed)
            .output(&out),
        ) as Arc<dyn Task>
    };
    let stat =
        || Arc::new(StatisticTask::new().statistic(&out, &med, Descriptor::Median));

    // direct puzzle path
    let b = PuzzleBuilder::new();
    replicate(&b, model(), &seed, 5, stat() as Arc<dyn Task>);
    let direct = MoleExecution::new(b.build().unwrap(), det_env(2), 31)
        .start()
        .unwrap();

    // experiment path
    let report = Experiment::new(Box::new(Replication {
        model: model(),
        seed_val: seed.clone(),
        replications: 5,
        statistic: stat() as Arc<dyn Task>,
        kind: "closure".into(),
        model_hooks: Vec::new(),
        statistic_hooks: Vec::new(),
    }))
    .on(det_env(2))
    .seed(31)
    .quiet()
    .run()
    .unwrap();

    assert_eq!(direct.outputs, report.outcome.outputs, "identical outputs");
    assert_eq!(direct.report.jobs, report.outcome.jobs);
}

#[test]
fn single_run_experiment_matches_direct_evaluation() {
    let evaluator: Arc<dyn Evaluator> = Arc::new(AntSimEvaluator::fast());
    let direct = evaluator.evaluate(&[125.0, 50.0, 50.0], 9).unwrap();

    let report = Experiment::new(Box::new(SingleRun {
        evaluator: Arc::clone(&evaluator),
        kind: "rust-sim".into(),
        population: 125.0,
        diffusion: 50.0,
        evaporation: 50.0,
        hooks: Vec::new(),
    }))
    .env(EnvSpec::Single {
        name: "local".into(),
        nodes: 1,
    })
    .seed(9)
    .quiet()
    .run()
    .unwrap();
    let out = &report.outcome.outputs[0];
    assert_eq!(out.get(&val_f64("food1")).unwrap(), direct[0]);
    assert_eq!(out.get(&val_f64("food2")).unwrap(), direct[1]);
    assert_eq!(out.get(&val_f64("food3")).unwrap(), direct[2]);
}

#[test]
fn single_environment_rejects_typos_in_the_cli_path() {
    // the satellite: `--env` with an unknown name is a hard error listing
    // the valid names, not a silent local fallback
    let err = single_environment("lcoal", 4, Arc::new(ThreadPool::new(1)), 1)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown environment `lcoal`"), "{err}");
    for name in ["local", "ssh", "pbs", "slurm", "sge", "oar", "condor", "egi"] {
        assert!(err.contains(name), "must list `{name}`: {err}");
    }
}
