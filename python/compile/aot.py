"""AOT lowering: JAX (L2, calling the L1 Pallas kernel) -> HLO text.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly (see
/opt/xla-example/load_hlo/gen_hlo.py).

Emits into ``artifacts/``:
  * ``ants_single.hlo.txt``  — fitness(params[3] f32, seed u32) -> ([3] f32,)
  * ``ants_batch{B}.hlo.txt``— vmapped fitness over B candidates
  * ``diffuse.hlo.txt``      — the bare L1 kernel (runtime smoke tests)
  * ``manifest.json``        — shapes/dtypes/settings the Rust runtime reads

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile does this
once; Python never runs on the request path).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import diffusion

BATCH_SIZES = (8, 32)


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text (return_tuple=True: the Rust
    side unwraps with ``to_tuple1``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_single(max_ticks: int) -> str:
    fn = model.make_fitness_fn(max_ticks=max_ticks)
    params = jax.ShapeDtypeStruct((3,), jnp.float32)
    seed = jax.ShapeDtypeStruct((), jnp.uint32)
    return to_hlo_text(jax.jit(lambda p, s: (fn(p, s),)).lower(params, seed))


def lower_batch(batch: int, max_ticks: int) -> str:
    fn = model.make_batch_fitness_fn(max_ticks=max_ticks)
    params = jax.ShapeDtypeStruct((batch, 3), jnp.float32)
    seeds = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    return to_hlo_text(jax.jit(lambda p, s: (fn(p, s),)).lower(params, seeds))


def lower_diffuse() -> str:
    w = model.WORLD
    chem = jax.ShapeDtypeStruct((w, w), jnp.float32)
    rate = jax.ShapeDtypeStruct((), jnp.float32)
    fn = lambda c, d, e: (diffusion.diffuse_evaporate(c, d, e),)
    return to_hlo_text(jax.jit(fn).lower(chem, rate, rate))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--max-ticks", type=int, default=model.MAX_TICKS)
    ap.add_argument("--skip-batches", action="store_true",
                    help="only emit the single-eval + diffuse artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = {}

    def emit(name: str, text: str, **meta) -> None:
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries[name] = {"file": f"{name}.hlo.txt", **meta}
        print(f"wrote {path} ({len(text)} chars)")

    emit("diffuse", lower_diffuse(),
         inputs=[["f32", [model.WORLD, model.WORLD]], ["f32", []], ["f32", []]],
         outputs=[["f32", [model.WORLD, model.WORLD]]])

    emit("ants_single", lower_single(args.max_ticks), batch=1,
         inputs=[["f32", [3]], ["u32", []]], outputs=[["f32", [3]]])

    if not args.skip_batches:
        for b in BATCH_SIZES:
            emit(f"ants_batch{b}", lower_batch(b, args.max_ticks), batch=b,
                 inputs=[["f32", [b, 3]], ["u32", [b]]],
                 outputs=[["f32", [b, 3]]])

    manifest = {
        "world": model.WORLD,
        "max_ants": model.MAX_ANTS,
        "max_ticks": args.max_ticks,
        "batch_sizes": [1] + ([] if args.skip_batches else list(BATCH_SIZES)),
        "objectives": ["final-ticks-food1", "final-ticks-food2",
                       "final-ticks-food3"],
        "params": ["gpopulation", "gdiffusion-rate", "gevaporation-rate"],
        "artifacts": entries,
    }
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
