"""L2: the NetLogo "Ants" foraging model as a pure JAX computation.

This is the workload the paper calibrates (§4): a colony of ants forages
from three food sources at different distances from the nest, dropping a
pheromone ("chemical") when returning with food. The calibration objective
is the tick at which each of the three sources becomes empty (lower is
better); the parameters are ``population``, ``diffusion-rate`` and
``evaporation-rate``.

Semantics follow Wilensky's ants.nlogo (headless version referenced by the
paper), with the deviations documented in DESIGN.md §7:

  * agents update synchronously (NetLogo ``ask`` is sequential);
  * simultaneous pick-ups from one patch may transiently over-pick — the
    food field is clamped at zero;
  * the tick loop is a fixed-length ``lax.scan`` (AOT needs static shapes);
    a source that never empties scores ``max_ticks``.

The per-tick field update (diffusion + evaporation) is delegated to the L1
Pallas kernel in :mod:`kernels.diffusion`.

Everything here runs at *build* time only: :mod:`aot` lowers the jitted
functions to HLO text artifacts which the Rust runtime loads via PJRT.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import diffusion, ref

# -- world geometry (NetLogo ants.nlogo defaults) ---------------------------
WORLD = 71            # patches per side; coordinates span -35..35
HALF = WORLD // 2     # 35 == max-pxcor == max-pycor
MAX_ANTS = 200        # static ant-array size; `population` masks the tail
MAX_TICKS = 1000      # default scan length (overridable per artifact)
NEST_RADIUS = 5.0
SOURCE_RADIUS = 5.0
# food source centres: (x, y) in NetLogo coords (§4.1, ants.nlogo setup)
SOURCES = ((0.6 * HALF, 0.0), (-0.6 * HALF, -0.6 * HALF), (-0.8 * HALF, 0.8 * HALF))
CHEMICAL_DROP = 60.0
SNIFF_THRESHOLD_LOW = 0.05
SNIFF_THRESHOLD_HIGH = 2.0
WIGGLE_MAX = 40.0     # rt random 40 / lt random 40


class World(NamedTuple):
    """Patch fields, built once per run from the seed."""

    food: jnp.ndarray           # [W, W] f32, remaining food units
    source_id: jnp.ndarray      # [W, W] i32, 1..3 or 0
    nest: jnp.ndarray           # [W, W] bool
    nest_scent: jnp.ndarray     # [W, W] f32, 200 - distance-to-nest


class Ants(NamedTuple):
    """Agent state arrays, all of length MAX_ANTS."""

    x: jnp.ndarray        # f32, NetLogo x coordinate in [-35, 35]
    y: jnp.ndarray        # f32
    heading: jnp.ndarray  # f32 degrees, 0 = north, clockwise (NetLogo)
    carrying: jnp.ndarray  # bool


class Carry(NamedTuple):
    """``lax.scan`` carry: the mutable simulation state."""

    food: jnp.ndarray
    chemical: jnp.ndarray
    ants: Ants
    # fitness latches: 0 until the source empties, then the emptying tick
    final_ticks: jnp.ndarray  # [3] f32


def _coord_grids():
    """NetLogo (x, y) coordinates of every patch; grid index [row, col] maps
    to (x = col - HALF, y = row - HALF)."""
    ys, xs = jnp.mgrid[0:WORLD, 0:WORLD]
    return (xs - HALF).astype(jnp.float32), (ys - HALF).astype(jnp.float32)


def setup_world(key: jnp.ndarray) -> World:
    """ants.nlogo ``setup``: nest scent field, three food sources with
    1-or-2 food units per patch (drawn from the run's RNG, as NetLogo does)."""
    px, py = _coord_grids()
    dist_nest = jnp.sqrt(px * px + py * py)
    nest = dist_nest < NEST_RADIUS
    nest_scent = 200.0 - dist_nest

    source_id = jnp.zeros((WORLD, WORLD), jnp.int32)
    for i, (sx, sy) in enumerate(SOURCES):
        d = jnp.sqrt((px - sx) ** 2 + (py - sy) ** 2)
        source_id = jnp.where(d < SOURCE_RADIUS, i + 1, source_id)

    # setup-food: set food one-of [1 2]
    amounts = jax.random.randint(key, (WORLD, WORLD), 1, 3).astype(jnp.float32)
    food = jnp.where(source_id > 0, amounts, 0.0)
    return World(food=food, source_id=source_id, nest=nest, nest_scent=nest_scent)


def init_ants(key: jnp.ndarray) -> Ants:
    """population turtles at the origin with random headings."""
    heading = jax.random.uniform(key, (MAX_ANTS,), jnp.float32, 0.0, 360.0)
    zeros = jnp.zeros((MAX_ANTS,), jnp.float32)
    return Ants(x=zeros, y=zeros, heading=heading,
                carrying=jnp.zeros((MAX_ANTS,), bool))


def _patch_index(x, y):
    """Round NetLogo coordinates to clamped [row, col] grid indices."""
    col = jnp.clip(jnp.round(x).astype(jnp.int32) + HALF, 0, WORLD - 1)
    row = jnp.clip(jnp.round(y).astype(jnp.int32) + HALF, 0, WORLD - 1)
    return row, col


def _sample(field, x, y):
    """Patch value at rounded (x, y), clamped to the world."""
    row, col = _patch_index(x, y)
    return field[row, col]


def _scent_at_angle(field, ants: Ants, angle):
    """NetLogo ``chemical-scent-at-angle``: the field one step ahead at
    heading+angle (patch-rounded)."""
    rad = jnp.deg2rad(ants.heading + angle)
    return _sample(field, ants.x + jnp.sin(rad), ants.y + jnp.cos(rad))


def _uphill(field, ants: Ants):
    """NetLogo ``uphill-chemical`` / ``uphill-nest-scent``: turn 45° toward
    the strongest of ahead / right / left, only if a side beats ahead."""
    ahead = _scent_at_angle(field, ants, 0.0)
    right = _scent_at_angle(field, ants, 45.0)
    left = _scent_at_angle(field, ants, -45.0)
    turn = jnp.where(right > left, 45.0, -45.0)
    better_side = (right > ahead) | (left > ahead)
    return jnp.where(better_side, ants.heading + turn, ants.heading)


def _in_world(x, y):
    return (jnp.abs(x) <= HALF) & (jnp.abs(y) <= HALF)


def _step(world_static, carry: Carry, tick, key, population,
          diffusion_rate, evaporation_rate, diffuse) -> Carry:
    """One NetLogo ``go`` tick, vectorised over all ants."""
    source_id, nest, nest_scent = world_static
    food, chemical, ants, final_ticks = carry

    idx = jnp.arange(MAX_ANTS, dtype=jnp.float32)
    # `if who >= ticks [ stop ]` — ants leave the nest gradually, and only
    # the first `population` turtles exist at all.
    active = (idx < population) & (idx < tick)

    row, col = _patch_index(ants.x, ants.y)
    food_here = food[row, col]
    nest_here = nest[row, col]
    chem_here = chemical[row, col]

    # --- look-for-food (not carrying) -------------------------------------
    picks_up = active & ~ants.carrying & (food_here > 0.0)
    sniffing = (
        active & ~ants.carrying & ~picks_up
        & (chem_here >= SNIFF_THRESHOLD_LOW) & (chem_here < SNIFF_THRESHOLD_HIGH)
    )
    heading_sniff = _uphill(chemical, ants)

    # --- return-to-nest (carrying) -----------------------------------------
    drops_food = active & ants.carrying & nest_here
    homing = active & ants.carrying & ~nest_here
    heading_home = _uphill(nest_scent, ants)

    heading = ants.heading
    heading = jnp.where(sniffing, heading_sniff, heading)
    heading = jnp.where(homing, heading_home, heading)
    heading = jnp.where(picks_up | drops_food, heading + 180.0, heading)

    carrying = (ants.carrying | picks_up) & ~drops_food

    # field writes: food pick-up and chemical drop (scatter-add)
    food = food.at[row, col].add(jnp.where(picks_up, -1.0, 0.0))
    food = jnp.maximum(food, 0.0)  # clamp transient over-picks
    chemical = chemical.at[row, col].add(jnp.where(homing, CHEMICAL_DROP, 0.0))

    # --- wiggle + fd 1 -----------------------------------------------------
    kr, kl = jax.random.split(key)
    heading = heading + jax.random.uniform(kr, (MAX_ANTS,), maxval=WIGGLE_MAX)
    heading = heading - jax.random.uniform(kl, (MAX_ANTS,), maxval=WIGGLE_MAX)
    rad = jnp.deg2rad(heading)
    nx, ny = ants.x + jnp.sin(rad), ants.y + jnp.cos(rad)
    # if not can-move? 1 [ rt 180 ] — bounce off the world edge
    blocked = ~_in_world(nx, ny)
    heading = jnp.where(blocked, heading + 180.0, heading)
    rad = jnp.deg2rad(heading)
    nx, ny = ants.x + jnp.sin(rad), ants.y + jnp.cos(rad)
    moved = active & _in_world(nx, ny)
    x = jnp.where(moved, nx, ants.x)
    y = jnp.where(moved, ny, ants.y)
    heading = jnp.mod(heading, 360.0)

    # --- patch updates: L1 fused diffuse + evaporate -----------------------
    chemical = diffuse(chemical, diffusion_rate, evaporation_rate)

    ants = Ants(x=x, y=y, heading=heading, carrying=carrying)

    # --- fitness latch: compute-fitness (paper Listing 1) -------------------
    remaining = jnp.stack([
        jnp.sum(jnp.where(source_id == s, food, 0.0)) for s in (1, 2, 3)
    ])
    now_empty = (remaining <= 0.0) & (final_ticks == 0.0)
    final_ticks = jnp.where(now_empty, tick, final_ticks)

    return Carry(food=food, chemical=chemical, ants=ants, final_ticks=final_ticks)


def make_fitness_fn(max_ticks: int = MAX_TICKS, use_pallas: bool = True):
    """Build the single-evaluation fitness function.

    Returns ``fitness(params, seed) -> [3] f32`` where
    ``params = [population, diffusion-rate, evaporation-rate]`` (f32) and
    ``seed`` is a uint32 scalar. Objectives are the first-empty ticks of the
    three food sources (``max_ticks`` if a source never empties).
    """
    diffuse = (diffusion.diffuse_evaporate if use_pallas
               else ref.diffuse_evaporate_ref)

    def fitness(params: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
        population = params[0]
        diffusion_rate = params[1]
        evaporation_rate = params[2]
        base = jax.random.PRNGKey(seed)
        k_world, k_ants, k_run = jax.random.split(base, 3)
        world = setup_world(k_world)
        ants = init_ants(k_ants)
        static = (world.source_id, world.nest, world.nest_scent)

        def body(carry: Carry, tick):
            key = jax.random.fold_in(k_run, tick)
            carry = _step(static, carry, tick.astype(jnp.float32), key,
                          population, diffusion_rate, evaporation_rate, diffuse)
            return carry, None

        carry0 = Carry(
            food=world.food,
            chemical=jnp.zeros((WORLD, WORLD), jnp.float32),
            ants=ants,
            final_ticks=jnp.zeros((3,), jnp.float32),
        )
        out, _ = jax.lax.scan(body, carry0, jnp.arange(1, max_ticks + 1))
        # sources that never emptied score max_ticks (penalty)
        return jnp.where(out.final_ticks == 0.0, float(max_ticks),
                         out.final_ticks)

    return fitness


def make_batch_fitness_fn(max_ticks: int = MAX_TICKS, use_pallas: bool = True):
    """vmapped fitness: ``(params[B,3], seeds[B]) -> fit[B,3]``.

    The batch size is whatever leading dimension the caller lowers with —
    :mod:`aot` emits one artifact per batch size in its ``BATCH_SIZES``.
    """
    single = make_fitness_fn(max_ticks=max_ticks, use_pallas=use_pallas)

    def batched(params: jnp.ndarray, seeds: jnp.ndarray) -> jnp.ndarray:
        return jax.vmap(single)(params, seeds)

    return batched
