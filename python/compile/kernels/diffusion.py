"""L1 Pallas kernel: fused pheromone diffusion + evaporation.

This is the per-tick hot-spot of the ant model: NetLogo's
``diffuse chemical (diffusion-rate / 100)`` followed by
``set chemical chemical * (100 - evaporation-rate) / 100``. The reference
implementation (`ref.py`) materialises a padded array plus eight shifted
views and two extra elementwise passes; the kernel fuses everything into a
single VMEM-resident pass: one load of the field, one store of the result.

TPU notes (design target; correctness is validated under ``interpret=True``
because the CPU PJRT plugin cannot execute Mosaic custom-calls):
  * the whole 71x71 f32 field is ~20 KB — it fits in a single VMEM block
    with room to spare, so the grid is ``()`` and BlockSpec covers the full
    array. No HBM round-trips between the diffusion and evaporation stages.
  * scalar parameters ride along as (1, 1) f32 blocks (SMEM-like usage).
  * the neighbour count is a compile-time constant of the world shape; it
    is folded into the kernel at trace time rather than streamed in.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _diffuse_kernel(nc_ref, d_ref, e_ref, x_ref, o_ref):
    """Fused diffuse+evaporate over one full-field block.

    ``nc_ref`` holds the in-world neighbour count — a constant of the world
    shape, computed once at trace time and passed as an input (Pallas
    forbids captured array constants).
    """
    x = x_ref[...]
    d = d_ref[0, 0] / 100.0
    keep = (100.0 - e_ref[0, 0]) / 100.0
    p = jnp.pad(x, 1)
    neigh = (
        p[:-2, :-2] + p[:-2, 1:-1] + p[:-2, 2:]
        + p[1:-1, :-2] + p[1:-1, 2:]
        + p[2:, :-2] + p[2:, 1:-1] + p[2:, 2:]
    )
    o_ref[...] = (x - x * d * (nc_ref[...] / 8.0) + (d / 8.0) * neigh) * keep


@functools.partial(jax.jit, static_argnames=())
def diffuse_evaporate(
    chemical: jnp.ndarray,
    diffusion_rate: jnp.ndarray,
    evaporation_rate: jnp.ndarray,
) -> jnp.ndarray:
    """Pallas-fused NetLogo ``diffuse`` + evaporation step.

    Drop-in replacement for :func:`ref.diffuse_evaporate_ref`.
    """
    h, w = chemical.shape
    nc = ref.neighbour_count((h, w), chemical.dtype)
    d = jnp.asarray(diffusion_rate, chemical.dtype).reshape(1, 1)
    e = jnp.asarray(evaporation_rate, chemical.dtype).reshape(1, 1)
    return pl.pallas_call(
        _diffuse_kernel,
        out_shape=jax.ShapeDtypeStruct((h, w), chemical.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(nc, d, e, chemical)
