"""Pure-jnp correctness oracle for the fused diffusion/evaporation kernel.

NetLogo semantics reproduced here (the L1 Pallas kernel must match this
bit-for-bit up to float tolerance):

``diffuse chemical d`` — every patch gives ``d/8`` of its value to each of
its eight Moore neighbours. Patches on the world edge have fewer than eight
neighbours; the shares destined for missing neighbours are *kept* by the
patch (NetLogo dictionary: "the patch keeps any leftover shares").

``set chemical chemical * (100 - evaporation-rate) / 100`` — uniform decay,
applied after diffusion, exactly as in the Ants model's ``go`` procedure.

The fused reference computes, for world-edge-aware neighbour count ``n``:

    out = (x - x * d * n/8 + (d/8) * sum_of_neighbours(x)) * keep

with ``keep = (100 - evaporation_rate) / 100`` and zero-padded neighbour
sums (the world does not wrap in the Ants model).
"""

from __future__ import annotations

import jax.numpy as jnp


def neighbour_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Sum of the 8 Moore neighbours with zero padding (non-wrapping world)."""
    p = jnp.pad(x, 1)
    return (
        p[:-2, :-2] + p[:-2, 1:-1] + p[:-2, 2:]
        + p[1:-1, :-2] + p[1:-1, 2:]
        + p[2:, :-2] + p[2:, 1:-1] + p[2:, 2:]
    )


def neighbour_count(shape, dtype=jnp.float32) -> jnp.ndarray:
    """Number of in-world Moore neighbours of each patch (8 inside, 5 on
    edges, 3 in corners). Static for a given world shape."""
    return neighbour_sum(jnp.ones(shape, dtype))


def diffuse_evaporate_ref(
    chemical: jnp.ndarray,
    diffusion_rate,
    evaporation_rate,
) -> jnp.ndarray:
    """One NetLogo tick of ``diffuse`` + evaporation on the chemical field.

    Args:
      chemical: ``[H, W]`` float32 pheromone field.
      diffusion_rate: scalar in ``[0, 100]`` (NetLogo slider units).
      evaporation_rate: scalar in ``[0, 100]``.
    Returns:
      The updated ``[H, W]`` field.
    """
    x = chemical
    d = jnp.asarray(diffusion_rate, x.dtype) / 100.0
    keep = (100.0 - jnp.asarray(evaporation_rate, x.dtype)) / 100.0
    n = neighbour_count(x.shape, x.dtype)
    out = x - x * d * (n / 8.0) + (d / 8.0) * neighbour_sum(x)
    return out * keep
