"""AOT path: the lowered HLO text must be parseable, runnable via
xla_client, and must agree with the directly-jitted model."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

FAST_TICKS = 50


def _run_hlo_text(text, args):
    """Compile HLO text with the in-process CPU client and execute."""
    from jax._src.lib import xla_client as xc
    client = xc.make_cpu_client()
    # parse via the HLO text round-trip the Rust runtime uses
    comp = xc._xla.hlo_module_from_text(text)
    exe = client.compile(comp.as_serialized_hlo_module_proto())
    bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


class TestLowering:
    def test_single_lowering_emits_valid_hlo(self):
        text = aot.lower_single(FAST_TICKS)
        assert "HloModule" in text
        assert "while" in text  # the tick scan lowers to a while loop

    def test_diffuse_lowering_small(self):
        text = aot.lower_diffuse()
        assert "HloModule" in text
        # a fused elementwise stencil: no while loop expected
        assert "ROOT" in text

    def test_batch_lowering_shapes(self):
        text = aot.lower_batch(4, FAST_TICKS)
        assert "f32[4,3]" in text.replace(" ", "")


class TestExecutesAndMatchesJit:
    def test_hlo_matches_jit_single(self):
        params = jnp.array([125.0, 50.0, 10.0], jnp.float32)
        seed = jnp.uint32(42)
        fit = jax.jit(model.make_fitness_fn(max_ticks=FAST_TICKS))
        want = np.asarray(fit(params, seed))
        text = aot.lower_single(FAST_TICKS)
        try:
            got = _run_hlo_text(text, [params, seed])
        except Exception as e:  # pragma: no cover - API drift guard
            pytest.skip(f"in-process HLO execution unavailable: {e}")
        np.testing.assert_allclose(np.asarray(got).reshape(3), want, atol=1e-4)


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_manifest_lists_all_artifacts(self, manifest):
        assert set(manifest["artifacts"]) >= {"diffuse", "ants_single"}
        d = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        for entry in manifest["artifacts"].values():
            assert os.path.exists(os.path.join(d, entry["file"]))

    def test_manifest_settings(self, manifest):
        assert manifest["world"] == model.WORLD
        assert manifest["max_ants"] == model.MAX_ANTS
        assert manifest["objectives"] == [
            "final-ticks-food1", "final-ticks-food2", "final-ticks-food3"]
