"""L2 correctness: shapes, determinism, fitness semantics of the JAX ant
model, and pallas-vs-ref equivalence of the full simulation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

jax.config.update("jax_platform_name", "cpu")

# Small configurations keep the test suite fast; artifact-scale settings are
# covered by the Rust integration tests against the real artifacts.
FAST_TICKS = 60


@pytest.fixture(scope="module")
def fit_fast():
    return jax.jit(model.make_fitness_fn(max_ticks=FAST_TICKS))


DEFAULT_PARAMS = jnp.array([125.0, 50.0, 50.0], jnp.float32)


class TestSetup:
    def test_world_shapes(self):
        w = model.setup_world(jax.random.PRNGKey(0))
        assert w.food.shape == (model.WORLD, model.WORLD)
        assert w.source_id.shape == (model.WORLD, model.WORLD)
        assert w.nest.dtype == jnp.bool_

    def test_three_food_sources_present(self):
        w = model.setup_world(jax.random.PRNGKey(0))
        for s in (1, 2, 3):
            patches = int(jnp.sum(w.source_id == s))
            assert patches > 0, f"source {s} missing"
            total = float(jnp.sum(jnp.where(w.source_id == s, w.food, 0.0)))
            # each source patch holds 1 or 2 units
            assert patches <= total <= 2 * patches

    def test_food_only_in_sources(self):
        w = model.setup_world(jax.random.PRNGKey(1))
        assert float(jnp.sum(jnp.where(w.source_id == 0, w.food, 0.0))) == 0.0

    def test_nest_scent_peaks_at_origin(self):
        w = model.setup_world(jax.random.PRNGKey(0))
        c = model.HALF
        assert float(w.nest_scent[c, c]) == pytest.approx(200.0)
        assert bool(w.nest[c, c])
        # scent decreases away from the nest
        assert float(w.nest_scent[c, c]) > float(w.nest_scent[c, c + 10])

    def test_sources_at_different_distances(self):
        """The paper's Pareto structure comes from sources at 3 distances."""
        dists = sorted(
            (sx * sx + sy * sy) ** 0.5 for sx, sy in model.SOURCES
        )
        assert dists[0] < dists[1] < dists[2]

    def test_init_ants_at_origin(self):
        a = model.init_ants(jax.random.PRNGKey(0))
        assert float(jnp.max(jnp.abs(a.x))) == 0.0
        assert a.heading.shape == (model.MAX_ANTS,)
        assert not bool(jnp.any(a.carrying))


class TestFitness:
    def test_shape_and_range(self, fit_fast):
        f = fit_fast(DEFAULT_PARAMS, jnp.uint32(42))
        assert f.shape == (3,)
        assert bool(jnp.all(f >= 1.0)) and bool(jnp.all(f <= FAST_TICKS))

    def test_deterministic_same_seed(self, fit_fast):
        a = fit_fast(DEFAULT_PARAMS, jnp.uint32(7))
        b = fit_fast(DEFAULT_PARAMS, jnp.uint32(7))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_seed_changes_outcome(self):
        """Replications (different seeds) must explore different stochastic
        realisations — the premise of the paper's §4.4. Checked at a horizon
        where the near source resolves (evaporation-rate 10, NetLogo default)."""
        fit = jax.jit(model.make_fitness_fn(max_ticks=350))
        params = jnp.array([125.0, 50.0, 10.0], jnp.float32)
        outs = [np.asarray(fit(params, jnp.uint32(s))) for s in range(4)]
        assert any(not np.array_equal(outs[0], o) for o in outs[1:])

    def test_zero_population_never_empties(self, fit_fast):
        """With no ants, all sources survive: fitness == max_ticks penalty."""
        f = fit_fast(jnp.array([0.0, 50.0, 50.0], jnp.float32), jnp.uint32(1))
        np.testing.assert_array_equal(np.asarray(f),
                                      [FAST_TICKS, FAST_TICKS, FAST_TICKS])

    def test_full_run_empties_near_source_first(self):
        """With persistent trails (evaporation-rate 10, the NetLogo slider
        default) the near source (source 1 at 0.6*35 ≈ 21 from the nest)
        empties, and no later than the far source (source 3)."""
        fit = jax.jit(model.make_fitness_fn(max_ticks=600))
        f = np.asarray(fit(jnp.array([125.0, 50.0, 10.0], jnp.float32),
                           jnp.uint32(42)))
        assert f[0] < 600.0, "near source never emptied in 600 ticks"
        assert f[0] <= f[2]

    def test_pallas_and_ref_paths_agree(self):
        fp = jax.jit(model.make_fitness_fn(max_ticks=FAST_TICKS, use_pallas=True))
        fr = jax.jit(model.make_fitness_fn(max_ticks=FAST_TICKS, use_pallas=False))
        a = fp(DEFAULT_PARAMS, jnp.uint32(3))
        b = fr(DEFAULT_PARAMS, jnp.uint32(3))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


class TestBatch:
    def test_batch_matches_single(self, fit_fast):
        batch = jax.jit(model.make_batch_fitness_fn(max_ticks=FAST_TICKS))
        params = jnp.stack([DEFAULT_PARAMS,
                            jnp.array([60.0, 20.0, 5.0], jnp.float32)])
        seeds = jnp.array([42, 43], jnp.uint32)
        bf = batch(params, seeds)
        assert bf.shape == (2, 3)
        s0 = fit_fast(params[0], seeds[0])
        np.testing.assert_allclose(np.asarray(bf[0]), np.asarray(s0), atol=1e-4)


class TestStepInvariants:
    def _state(self, seed=0):
        w = model.setup_world(jax.random.PRNGKey(seed))
        a = model.init_ants(jax.random.PRNGKey(seed + 1))
        c = model.Carry(food=w.food,
                        chemical=jnp.zeros((model.WORLD, model.WORLD)),
                        ants=a, final_ticks=jnp.zeros((3,)))
        static = (w.source_id, w.nest, w.nest_scent)
        return static, c

    def _run(self, n, population=125.0, seed=0):
        from compile.kernels import ref as kref
        static, c = self._state(seed)
        for t in range(1, n + 1):
            key = jax.random.fold_in(jax.random.PRNGKey(99), t)
            c = model._step(static, c, float(t), key, population,
                            50.0, 10.0, kref.diffuse_evaporate_ref)
        return c

    def test_ants_stay_in_world(self):
        c = self._run(30)
        assert float(jnp.max(jnp.abs(c.ants.x))) <= model.HALF
        assert float(jnp.max(jnp.abs(c.ants.y))) <= model.HALF

    def test_food_monotone_nonincreasing(self):
        c10 = self._run(10)
        c30 = self._run(30)
        assert float(jnp.sum(c30.food)) <= float(jnp.sum(c10.food))
        assert bool(jnp.all(c30.food >= 0.0))

    def test_chemical_nonnegative(self):
        c = self._run(30)
        assert bool(jnp.all(c.chemical >= 0.0))

    def test_inactive_ants_do_not_move(self):
        c = self._run(5, population=3.0)
        # ants beyond the population never activate
        assert float(jnp.max(jnp.abs(c.ants.x[10:]))) == 0.0

    def test_staggered_departure(self):
        """`if who >= ticks [stop]`: after k ticks at most k ants have moved."""
        c = self._run(4)
        moved = jnp.sum((jnp.abs(c.ants.x) > 0) | (jnp.abs(c.ants.y) > 0))
        assert int(moved) <= 4


class TestModelProperties:
    """Hypothesis sweeps over the parameter space (L2 invariants)."""

    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        pop=st.floats(0.0, 200.0, width=32),
        d=st.floats(0.0, 99.0, width=32),
        e=st.floats(0.0, 99.0, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fitness_always_in_range(self, pop, d, e, seed):
        fit = jax.jit(model.make_fitness_fn(max_ticks=40))
        f = np.asarray(fit(jnp.array([pop, d, e], jnp.float32), jnp.uint32(seed)))
        assert f.shape == (3,)
        assert np.all(f >= 1.0) and np.all(f <= 40.0)
        assert not np.any(np.isnan(f))

    @settings(max_examples=8, deadline=None)
    @given(
        d=st.floats(0.0, 99.0, width=32),
        e=st.floats(0.0, 99.0, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_batch_consistent_with_single(self, d, e, seed):
        """vmapped evaluation must agree with the scalar path for any
        parameters — the property the Rust batch packer relies on."""
        single = jax.jit(model.make_fitness_fn(max_ticks=30))
        batch = jax.jit(model.make_batch_fitness_fn(max_ticks=30))
        p = jnp.array([125.0, d, e], jnp.float32)
        s = jnp.uint32(seed)
        a = np.asarray(single(p, s))
        b = np.asarray(batch(p[None, :], s[None]))[0]
        np.testing.assert_allclose(a, b, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_world_setup_structure_invariant_to_seed(self, seed):
        """Only food *amounts* are stochastic; geometry is fixed."""
        w = model.setup_world(jax.random.PRNGKey(seed))
        ref_w = model.setup_world(jax.random.PRNGKey(0))
        np.testing.assert_array_equal(np.asarray(w.source_id),
                                      np.asarray(ref_w.source_id))
        np.testing.assert_array_equal(np.asarray(w.nest), np.asarray(ref_w.nest))
        # amounts in {1, 2} on source patches
        amounts = np.asarray(w.food)[np.asarray(w.source_id) > 0]
        assert set(np.unique(amounts)) <= {1.0, 2.0}
