"""L1 correctness: the Pallas fused diffusion/evaporation kernel must match
the pure-jnp oracle (kernels.ref) — the CORE correctness signal — plus
NetLogo-semantics invariants of the oracle itself."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import diffusion, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_field(shape, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape,
                              jnp.float32, 0.0, 100.0)


class TestKernelVsRef:
    @pytest.mark.parametrize("shape", [(71, 71), (8, 8), (1, 1), (3, 17)])
    @pytest.mark.parametrize("d,e", [(50.0, 10.0), (0.0, 0.0), (100.0, 100.0),
                                     (99.0, 1.0), (20.0, 15.0)])
    def test_matches_reference(self, shape, d, e):
        x = _rand_field(shape)
        got = diffusion.diffuse_evaporate(x, d, e)
        want = ref.diffuse_evaporate_ref(x, d, e)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-5)

    @settings(max_examples=40, deadline=None)
    @given(
        h=st.integers(1, 40), w=st.integers(1, 40),
        d=st.floats(0.0, 100.0, allow_nan=False, width=32),
        e=st.floats(0.0, 100.0, allow_nan=False, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference_hypothesis(self, h, w, d, e, seed):
        """Property sweep over shapes and rate ranges."""
        x = _rand_field((h, w), seed=seed)
        got = diffusion.diffuse_evaporate(x, d, e)
        want = ref.diffuse_evaporate_ref(x, d, e)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    def test_jit_and_scan_compose(self):
        """The kernel must lower inside jit+scan (the L2 usage pattern)."""
        x = _rand_field((16, 16))

        def body(c, _):
            return diffusion.diffuse_evaporate(c, 50.0, 10.0), None

        out, _ = jax.jit(lambda c: jax.lax.scan(body, c, None, length=5))(x)
        want = x
        for _ in range(5):
            want = ref.diffuse_evaporate_ref(want, 50.0, 10.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)


class TestNetLogoSemantics:
    """Invariants of the oracle, from the NetLogo dictionary."""

    def test_diffusion_conserves_mass_interior(self):
        """With no evaporation, `diffuse` conserves total chemical: leftover
        shares at edges are kept by the patch."""
        x = _rand_field((31, 31), seed=3)
        out = ref.diffuse_evaporate_ref(x, 70.0, 0.0)
        np.testing.assert_allclose(float(jnp.sum(out)), float(jnp.sum(x)),
                                    rtol=1e-5)

    def test_zero_diffusion_is_pure_decay(self):
        x = _rand_field((9, 9), seed=4)
        out = ref.diffuse_evaporate_ref(x, 0.0, 25.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 0.75,
                                   rtol=1e-6)

    def test_full_evaporation_zeroes_field(self):
        x = _rand_field((9, 9), seed=5)
        out = ref.diffuse_evaporate_ref(x, 50.0, 100.0)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

    def test_point_source_spreads_to_8_neighbours(self):
        x = jnp.zeros((5, 5), jnp.float32).at[2, 2].set(8.0)
        out = ref.diffuse_evaporate_ref(x, 100.0, 0.0)
        # centre keeps nothing (interior patch, d=1), each neighbour gets 1
        assert float(out[2, 2]) == pytest.approx(0.0, abs=1e-6)
        for dr in (-1, 0, 1):
            for dc in (-1, 0, 1):
                if dr or dc:
                    assert float(out[2 + dr, 2 + dc]) == pytest.approx(1.0, abs=1e-6)

    def test_corner_keeps_leftover_shares(self):
        """A corner patch has 3 neighbours: with d=1 it keeps 5/8 of its value."""
        x = jnp.zeros((5, 5), jnp.float32).at[0, 0].set(8.0)
        out = ref.diffuse_evaporate_ref(x, 100.0, 0.0)
        assert float(out[0, 0]) == pytest.approx(5.0, abs=1e-6)

    def test_nonnegativity_preserved(self):
        x = _rand_field((13, 13), seed=6)
        out = ref.diffuse_evaporate_ref(x, 80.0, 30.0)
        assert bool(jnp.all(out >= 0.0))

    @settings(max_examples=25, deadline=None)
    @given(d=st.floats(0.0, 100.0, width=32), seed=st.integers(0, 1000))
    def test_mass_conservation_property(self, d, seed):
        x = _rand_field((17, 17), seed=seed)
        out = ref.diffuse_evaporate_ref(x, d, 0.0)
        np.testing.assert_allclose(float(jnp.sum(out)), float(jnp.sum(x)),
                                    rtol=1e-4)
